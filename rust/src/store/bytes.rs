//! Checked little-endian byte cursors for section payloads, plus the
//! owned/mapped dual representation behind zero-copy serving.
//!
//! [`ByteWriter`] appends into a growable buffer; [`ByteReader`] walks a
//! borrowed slice and returns [`StoreError::Corrupt`] on any out-of-bounds
//! or malformed read — snapshot loading must never panic on bad input.
//! Slice reads validate the declared element count against the bytes that
//! actually remain *before* allocating, so a corrupted length field cannot
//! trigger a huge allocation.
//!
//! # Alignment and the mapped load path
//!
//! In the current container format every slice field (`put_bytes`,
//! `put_u32s`, `put_u64s`, `put_usizes`) is preceded by zero padding up to
//! the next 8-byte boundary, so its length prefix *and* its element data
//! sit 8-aligned relative to the payload start. Section payloads start
//! 8-aligned in the file and mappings are page-aligned, so on a mapped
//! snapshot every element array is correctly aligned in memory — the
//! `*_ref` getters ([`ByteReader::get_u64s_ref`] /
//! [`ByteReader::get_u32s_ref`] / [`ByteReader::get_bytes_ref`]) can hand
//! out [`PodVec`]s that *borrow* the mapping instead of copying the
//! payload. Legacy (pre-v3) payloads are unpadded; readers for them run
//! with padding disabled ([`ByteReader::legacy`]) and the `*_ref` getters
//! silently fall back to owned copies, bumping a global counter
//! ([`mapped_borrow_fallbacks`]) that the cold-start test pins at zero for
//! current-format mapped loads.

use super::mmap::Mmap;
use super::StoreError;
use crate::util::HeapSize;
use std::ops::{Deref, Range};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Global count of `*_ref` reads that *wanted* to borrow from a mapping
/// but had to copy instead (misaligned element data — a legacy payload —
/// or a big-endian host). Reads without a backing mapping never count:
/// owned loads are expected to copy. The zero-copy contract of the mapped
/// load path is `mapped_borrow_fallbacks()` staying flat across a load,
/// enforced by `rust/tests/snapshot_cold_start.rs`.
static MAPPED_BORROW_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Reads the global fallback-copy counter. See the module docs; test-only
/// in spirit but harmless (and cheap) to expose.
pub fn mapped_borrow_fallbacks() -> u64 {
    MAPPED_BORROW_FALLBACKS.load(Ordering::Relaxed)
}

/// A reference-counted, immutable byte region: either an owned heap
/// buffer or a slice of a read-only file mapping. Cloning and
/// [`Bytes::slice`] are pointer adjustments — the underlying region is
/// shared, and the last clone to drop releases it (frees the buffer or
/// unmaps the file).
#[derive(Clone)]
pub struct Bytes {
    ptr: *const u8,
    len: usize,
    region: Region,
}

#[derive(Clone)]
enum Region {
    Heap(Arc<Vec<u8>>),
    Map(Arc<Mmap>),
}

// Safety: the region is immutable and pinned for the lifetime of every
// clone — a `Vec` behind an `Arc` never reallocates, and a mapping is
// only unmapped when the last `Arc` drops — so the derived pointer stays
// valid and the bytes can be read from any thread.
unsafe impl Send for Bytes {}
unsafe impl Sync for Bytes {}

impl Bytes {
    /// Takes ownership of a heap buffer.
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let region = Arc::new(v);
        let (ptr, len) = (region.as_ptr(), region.len());
        Bytes { ptr, len, region: Region::Heap(region) }
    }

    /// Wraps a whole file mapping.
    pub fn from_map(m: Arc<Mmap>) -> Bytes {
        let s = m.as_slice();
        let (ptr, len) = (s.as_ptr(), s.len());
        Bytes { ptr, len, region: Region::Map(m) }
    }

    /// Whether the region is a file mapping (as opposed to owned heap).
    pub fn is_mapped(&self) -> bool {
        matches!(self.region, Region::Map(_))
    }

    /// The backing file mapping, when there is one — the residency
    /// gauge (`mincore`) probes through this.
    pub fn mapping(&self) -> Option<&Arc<Mmap>> {
        match &self.region {
            Region::Map(m) => Some(m),
            Region::Heap(_) => None,
        }
    }

    /// A sub-range sharing the same region. Panics on out-of-bounds
    /// ranges, exactly like slice indexing.
    pub fn slice(&self, range: Range<usize>) -> Bytes {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "Bytes::slice: range {range:?} out of bounds for length {}",
            self.len
        );
        Bytes {
            // Safety: start <= len, so the offset stays inside (or one
            // past) the region.
            ptr: unsafe { self.ptr.add(range.start) },
            len: range.end - range.start,
            region: self.region.clone(),
        }
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        // Safety: `ptr`/`len` delimit live bytes of the pinned region
        // (see the Send/Sync note above).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Deref for Bytes {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "heap" };
        write!(f, "Bytes({kind}, {} bytes)", self.len)
    }
}

/// Marker for fixed-size little-endian element types whose arrays can be
/// borrowed directly from an aligned mapped payload (`u32` / `u64`).
pub trait Pod: Copy + PartialEq + std::fmt::Debug + 'static {}

impl Pod for u32 {}
impl Pod for u64 {}

/// A `Vec`-or-mapping array of plain elements: the storage type behind
/// every payload-sized field of the persistent structures. Reads go
/// through `Deref<Target = [T]>` (one predictable branch); writers call
/// [`PodVec::to_mut`], which converts a mapped array into an owned `Vec`
/// once and then edits in place — the write path never mutates a mapping.
pub struct PodVec<T: Pod> {
    repr: Repr<T>,
}

enum Repr<T> {
    Owned(Vec<T>),
    /// Invariants (checked at construction): the byte length is a
    /// multiple of `size_of::<T>()`, the base pointer is aligned for `T`,
    /// and the target is little-endian (elements are stored LE).
    Mapped(Bytes),
}

/// `PodVec<u64>` — plane words, bit-vector words, hash-table slots.
pub type Words = PodVec<u64>;

/// `PodVec<u32>` — posting lists, offsets, rank directories.
pub type U32s = PodVec<u32>;

impl<T: Pod> PodVec<T> {
    /// Wraps an aligned little-endian byte region without copying.
    /// Private: only the checked `*_ref` getters construct this.
    fn mapped(bytes: Bytes) -> PodVec<T> {
        debug_assert!(cfg!(target_endian = "little"));
        debug_assert_eq!(bytes.len() % std::mem::size_of::<T>(), 0);
        debug_assert_eq!(bytes.as_slice().as_ptr() as usize % std::mem::align_of::<T>(), 0);
        PodVec { repr: Repr::Mapped(bytes) }
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        self
    }

    /// Whether the elements are served from a file mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(self.repr, Repr::Mapped(_))
    }

    /// Mutable access, converting a mapped array into an owned `Vec` on
    /// first use. Build and write paths call this; serving structures
    /// loaded from a mapping stay borrowed because nothing mutates them.
    pub fn to_mut(&mut self) -> &mut Vec<T> {
        if matches!(self.repr, Repr::Mapped(_)) {
            let owned: Vec<T> = self.as_slice().to_vec();
            self.repr = Repr::Owned(owned);
        }
        match &mut self.repr {
            Repr::Owned(v) => v,
            Repr::Mapped(_) => unreachable!("just converted to owned"),
        }
    }
}

impl<T: Pod> Deref for PodVec<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match &self.repr {
            Repr::Owned(v) => v,
            Repr::Mapped(b) => {
                // Safety: construction checked alignment, size multiple
                // and endianness; the region is immutable and pinned.
                unsafe {
                    std::slice::from_raw_parts(
                        b.as_slice().as_ptr() as *const T,
                        b.len() / std::mem::size_of::<T>(),
                    )
                }
            }
        }
    }
}

impl<T: Pod> From<Vec<T>> for PodVec<T> {
    fn from(v: Vec<T>) -> Self {
        PodVec { repr: Repr::Owned(v) }
    }
}

impl<T: Pod> Default for PodVec<T> {
    fn default() -> Self {
        PodVec { repr: Repr::Owned(Vec::new()) }
    }
}

impl<T: Pod> Clone for PodVec<T> {
    fn clone(&self) -> Self {
        match &self.repr {
            Repr::Owned(v) => PodVec { repr: Repr::Owned(v.clone()) },
            Repr::Mapped(b) => PodVec { repr: Repr::Mapped(b.clone()) },
        }
    }
}

impl<T: Pod> PartialEq for PodVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod> std::fmt::Debug for PodVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Pod> HeapSize for PodVec<T> {
    fn heap_bytes(&self) -> usize {
        match &self.repr {
            Repr::Owned(v) => v.heap_bytes(),
            // Mapped elements live in the page cache, not the heap —
            // exactly the RSS the zero-copy mode saves.
            Repr::Mapped(_) => 0,
        }
    }
}

/// Append-only little-endian encoder. The default writer emits the
/// current (aligned) format; [`ByteWriter::legacy`] reproduces the
/// pre-v3 unpadded layout for compatibility tests.
#[derive(Debug)]
pub struct ByteWriter {
    buf: Vec<u8>,
    /// Zero-pad to 8-byte boundaries before slice fields (v3 format).
    padded: bool,
}

impl Default for ByteWriter {
    fn default() -> Self {
        ByteWriter::new()
    }
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new(), padded: true }
    }

    /// A writer emitting the unpadded pre-v3 slice layout. Only
    /// compatibility tests build legacy payloads; production writers
    /// always emit the current format.
    pub fn legacy() -> Self {
        ByteWriter { buf: Vec::new(), padded: false }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Zero-pads to the next 8-byte boundary (current format only).
    #[inline]
    fn pad_align8(&mut self) {
        if self.padded {
            let pad = (8 - self.buf.len() % 8) % 8;
            self.buf.extend_from_slice(&[0u8; 8][..pad]);
        }
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` values travel as `u64` (the format is 64-bit regardless of
    /// the writing host).
    #[inline]
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.pad_align8();
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.pad_align8();
        self.put_usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_u64s(&mut self, v: &[u64]) {
        self.pad_align8();
        self.put_usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_usizes(&mut self, v: &[usize]) {
        self.pad_align8();
        self.put_usize(v.len());
        for &x in v {
            self.put_u64(x as u64);
        }
    }
}

/// Checked decoder over a borrowed payload slice.
///
/// When the payload comes from a mapped snapshot section, `backing`
/// carries a [`Bytes`] handle spanning exactly `buf`; the `*_ref` getters
/// use it to hand out borrows of the mapping. Owned loads leave `backing`
/// unset, so the same getters copy — one code path per structure serves
/// both modes.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Whether slice fields are 8-aligned with zero padding (v3 format).
    padded: bool,
    /// The shared region `buf` was sliced from, when serving mapped.
    backing: Option<Bytes>,
}

impl<'a> ByteReader<'a> {
    /// Reader for a current-format (aligned) payload with no backing
    /// region — `*_ref` getters copy. Matches [`ByteWriter::new`].
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0, padded: true, backing: None }
    }

    /// Reader for a pre-v3 unpadded payload. Matches
    /// [`ByteWriter::legacy`].
    pub fn legacy(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0, padded: false, backing: None }
    }

    /// Reader over a snapshot section: `backing`, when present, must span
    /// exactly `buf`; `padded` reflects the container format version.
    pub(crate) fn with_backing(buf: &'a [u8], backing: Option<Bytes>, padded: bool) -> Self {
        debug_assert!(backing
            .as_ref()
            .map_or(true, |b| b.len() == buf.len() && b.as_slice().as_ptr() == buf.as_ptr()));
        ByteReader { buf, pos: 0, padded, backing }
    }

    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if n > self.remaining() {
            return Err(StoreError::corrupt(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Consumes the zero padding preceding a slice field (current format
    /// only). Nonzero pad bytes mean writer/reader disagreement.
    fn consume_pad(&mut self) -> Result<(), StoreError> {
        if !self.padded {
            return Ok(());
        }
        let pad = (8 - self.pos % 8) % 8;
        let s = self.take(pad)?;
        if s.iter().any(|&b| b != 0) {
            return Err(StoreError::corrupt(format!(
                "nonzero alignment padding before offset {}",
                self.pos
            )));
        }
        Ok(())
    }

    #[inline]
    pub fn get_u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn get_u32(&mut self) -> Result<u32, StoreError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes(s.try_into().unwrap()))
    }

    #[inline]
    pub fn get_u64(&mut self) -> Result<u64, StoreError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    #[inline]
    pub fn get_usize(&mut self) -> Result<usize, StoreError> {
        let v = self.get_u64()?;
        usize::try_from(v)
            .map_err(|_| StoreError::corrupt(format!("length {v} exceeds this platform's usize")))
    }

    /// Reads a declared element count, refusing counts that cannot fit in
    /// the remaining bytes (`elem_size` bytes per element).
    fn get_len(&mut self, elem_size: usize) -> Result<usize, StoreError> {
        let n = self.get_usize()?;
        let need = n
            .checked_mul(elem_size)
            .ok_or_else(|| StoreError::corrupt(format!("element count {n} overflows")))?;
        if need > self.remaining() {
            return Err(StoreError::corrupt(format!(
                "declared {n} elements ({need} bytes) but only {} bytes remain",
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8], StoreError> {
        self.consume_pad()?;
        let n = self.get_len(1)?;
        self.take(n)
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }

    /// Like [`Self::get_u32s`], but borrows the mapping when one backs
    /// this payload and the element data is aligned — the zero-copy load
    /// path. Without a backing mapping it copies (owned loads).
    pub fn get_u32s_ref(&mut self) -> Result<U32s, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(4)?;
        let start = self.pos;
        let raw = self.take(n * 4)?;
        if let Some(backing) = &self.backing {
            if cfg!(target_endian = "little") && raw.as_ptr() as usize % 4 == 0 {
                return Ok(U32s::mapped(backing.slice(start..start + n * 4)));
            }
            MAPPED_BORROW_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        }
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect::<Vec<u32>>()
            .into())
    }

    /// Like [`Self::get_u64s`], but borrows the mapping when possible.
    /// See [`Self::get_u32s_ref`].
    pub fn get_u64s_ref(&mut self) -> Result<Words, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(8)?;
        let start = self.pos;
        let raw = self.take(n * 8)?;
        if let Some(backing) = &self.backing {
            if cfg!(target_endian = "little") && raw.as_ptr() as usize % 8 == 0 {
                return Ok(Words::mapped(backing.slice(start..start + n * 8)));
            }
            MAPPED_BORROW_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        }
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect::<Vec<u64>>()
            .into())
    }

    /// Like [`Self::get_bytes`], but returns a shared handle that borrows
    /// the mapping when one backs this payload (bytes need no alignment).
    pub fn get_bytes_ref(&mut self) -> Result<Bytes, StoreError> {
        self.consume_pad()?;
        let n = self.get_len(1)?;
        let start = self.pos;
        let raw = self.take(n)?;
        if let Some(backing) = &self.backing {
            return Ok(backing.slice(start..start + n));
        }
        Ok(Bytes::from_vec(raw.to_vec()))
    }

    /// Errors unless the payload was consumed exactly — trailing garbage
    /// means the reader and writer disagree about the layout.
    pub fn expect_end(&self) -> Result<(), StoreError> {
        if self.remaining() != 0 {
            return Err(StoreError::corrupt(format!(
                "{} unread trailing bytes in section payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_usize(42);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_usize().unwrap(), 42);
        r.expect_end().unwrap();
    }

    #[test]
    fn slice_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[u64::MAX, 0]);
        w.put_bytes(b"hello");
        w.put_usizes(&[9, 8]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u64s().unwrap(), vec![u64::MAX, 0]);
        assert_eq!(r.get_bytes().unwrap(), b"hello");
        assert_eq!(r.get_usizes().unwrap(), vec![9, 8]);
        r.expect_end().unwrap();
    }

    #[test]
    fn slice_fields_are_8_aligned_after_odd_scalars() {
        // Tag bytes misalign the cursor; padding must realign every slice
        // field's length prefix and element data to 8 bytes.
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u64s(&[10, 20]);
        w.put_u8(2);
        w.put_u32(3);
        w.put_u32s(&[7, 8, 9]);
        w.put_u8(4);
        w.put_bytes(b"xyz");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 1);
        assert_eq!(r.get_u64s().unwrap(), vec![10, 20]);
        assert_eq!(r.get_u8().unwrap(), 2);
        assert_eq!(r.get_u32().unwrap(), 3);
        assert_eq!(r.get_u32s().unwrap(), vec![7, 8, 9]);
        assert_eq!(r.get_u8().unwrap(), 4);
        assert_eq!(r.get_bytes().unwrap(), b"xyz");
        r.expect_end().unwrap();
        // The u64 element data (first slice field after a 1-byte tag)
        // starts at offset 16: 7 pad + 8 count.
        assert_eq!(&bytes[1..8], &[0u8; 7]);
        assert_eq!(u64::from_le_bytes(bytes[8..16].try_into().unwrap()), 2);
        assert_eq!(u64::from_le_bytes(bytes[16..24].try_into().unwrap()), 10);
    }

    #[test]
    fn legacy_writer_matches_pre_v3_layout() {
        // The unpadded layout: count immediately follows the cursor.
        let mut w = ByteWriter::legacy();
        w.put_u8(1);
        w.put_u32s(&[5, 6]);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 1 + 8 + 8);
        assert_eq!(u64::from_le_bytes(bytes[1..9].try_into().unwrap()), 2);
        let mut r = ByteReader::legacy(&bytes);
        assert_eq!(r.get_u8().unwrap(), 1);
        assert_eq!(r.get_u32s().unwrap(), vec![5, 6]);
        r.expect_end().unwrap();
    }

    #[test]
    fn nonzero_padding_rejected() {
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u64s(&[10]);
        let mut bytes = w.into_bytes();
        bytes[3] = 0xAB; // inside the 7 pad bytes after the tag
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 1);
        assert!(r.get_u64s().is_err());
    }

    #[test]
    fn truncated_reads_error() {
        let mut w = ByteWriter::new();
        w.put_u64(1);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn oversized_declared_length_rejected_before_alloc() {
        // length field claims 2^60 u64s — must error, not allocate.
        let mut w = ByteWriter::new();
        w.put_u64(1u64 << 60);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_u64s().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = ByteWriter::new();
        w.put_u32(1);
        w.put_u32(2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let _ = r.get_u32().unwrap();
        assert!(r.expect_end().is_err());
    }

    #[test]
    fn ref_getters_copy_without_backing() {
        let mut w = ByteWriter::new();
        w.put_u64s(&[1, 2, 3]);
        w.put_u32s(&[4, 5]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let words = r.get_u64s_ref().unwrap();
        let ids = r.get_u32s_ref().unwrap();
        assert!(!words.is_mapped() && !ids.is_mapped());
        assert_eq!(&words[..], &[1, 2, 3]);
        assert_eq!(&ids[..], &[4, 5]);
        r.expect_end().unwrap();
    }

    #[test]
    fn ref_getters_borrow_with_backing() {
        let before = mapped_borrow_fallbacks();
        let mut w = ByteWriter::new();
        w.put_u8(9); // misaligning tag, absorbed by padding
        w.put_u64s(&[11, 22, 33]);
        w.put_u32s(&[44, 55]);
        w.put_bytes(b"tail");
        let backing = Bytes::from_vec(w.into_bytes());
        if backing.as_slice().as_ptr() as usize % 8 != 0 {
            // Heap-backed `Bytes` stands in for a mapping here; that only
            // works when the allocator handed back an 8-aligned buffer
            // (real mappings are page-aligned). Skip on the rare miss.
            return;
        }
        let buf: &[u8] = backing.as_slice();
        let mut r = ByteReader::with_backing(buf, Some(backing.clone()), true);
        assert_eq!(r.get_u8().unwrap(), 9);
        let words = r.get_u64s_ref().unwrap();
        let ids = r.get_u32s_ref().unwrap();
        let tail = r.get_bytes_ref().unwrap();
        r.expect_end().unwrap();
        assert_eq!(&words[..], &[11, 22, 33]);
        assert_eq!(&ids[..], &[44, 55]);
        assert_eq!(&tail[..], b"tail");
        // Borrowed, not copied: the slices point into the backing region.
        let range = backing.as_slice().as_ptr() as usize
            ..backing.as_slice().as_ptr() as usize + backing.len();
        assert!(range.contains(&(words.as_slice().as_ptr() as usize)));
        assert!(range.contains(&(ids.as_slice().as_ptr() as usize)));
        assert!(range.contains(&(tail.as_slice().as_ptr() as usize)));
        assert_eq!(mapped_borrow_fallbacks(), before, "no fallback copies");
        assert_eq!(words.heap_bytes(), 0, "borrowed words own no heap");
    }

    #[test]
    fn misaligned_backing_falls_back_to_copy_and_counts() {
        // Legacy (unpadded) layout: after a 1-byte tag the u64 element
        // data sits at offset 9 — unaligned, so a backed reader must copy
        // and record the fallback.
        let mut w = ByteWriter::legacy();
        w.put_u8(1);
        w.put_u64s(&[10, 20]);
        let backing = Bytes::from_vec(w.into_bytes());
        let buf: &[u8] = backing.as_slice();
        let before = mapped_borrow_fallbacks();
        let mut r = ByteReader::with_backing(buf, Some(backing.clone()), false);
        assert_eq!(r.get_u8().unwrap(), 1);
        let words = r.get_u64s_ref().unwrap();
        r.expect_end().unwrap();
        assert_eq!(&words[..], &[10, 20]);
        assert!(!words.is_mapped());
        assert_eq!(mapped_borrow_fallbacks(), before + 1);
    }

    #[test]
    fn podvec_to_mut_converts_and_edits() {
        let backing = Bytes::from_vec(42u64.to_le_bytes().to_vec());
        if backing.as_slice().as_ptr() as usize % 8 != 0 {
            return; // see ref_getters_borrow_with_backing
        }
        let mut v = Words::mapped(backing);
        assert!(v.is_mapped());
        assert_eq!(&v[..], &[42]);
        v.to_mut().push(43);
        assert!(!v.is_mapped());
        assert_eq!(&v[..], &[42, 43]);
        assert!(v.heap_bytes() >= 16);
    }

    #[test]
    fn podvec_semantics_match_vec() {
        let a: U32s = vec![1, 2, 3].into();
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert_eq!(a[1], 2);
        assert_eq!(a.iter().sum::<u32>(), 6);
        let d = U32s::default();
        assert!(d.is_empty());
        assert_eq!(format!("{a:?}"), "[1, 2, 3]");
    }

    #[test]
    fn bytes_slice_shares_region() {
        let b = Bytes::from_vec((0u8..32).collect());
        let s = b.slice(8..16);
        assert_eq!(&s[..], &(8u8..16).collect::<Vec<u8>>()[..]);
        assert!(!s.is_mapped());
        drop(b); // region survives through the slice's Arc
        assert_eq!(s[0], 8);
    }
}
