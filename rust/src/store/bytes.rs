//! Checked little-endian byte cursors for section payloads.
//!
//! [`ByteWriter`] appends into a growable buffer; [`ByteReader`] walks a
//! borrowed slice and returns [`StoreError::Corrupt`] on any out-of-bounds
//! or malformed read — snapshot loading must never panic on bad input.
//! Slice reads validate the declared element count against the bytes that
//! actually remain *before* allocating, so a corrupted length field cannot
//! trigger a huge allocation.

use super::StoreError;

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` values travel as `u64` (the format is 64-bit regardless of
    /// the writing host).
    #[inline]
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_u64(x as u64);
        }
    }
}

/// Checked decoder over a borrowed payload slice.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if n > self.remaining() {
            return Err(StoreError::corrupt(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn get_u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn get_u32(&mut self) -> Result<u32, StoreError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes(s.try_into().unwrap()))
    }

    #[inline]
    pub fn get_u64(&mut self) -> Result<u64, StoreError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    #[inline]
    pub fn get_usize(&mut self) -> Result<usize, StoreError> {
        let v = self.get_u64()?;
        usize::try_from(v)
            .map_err(|_| StoreError::corrupt(format!("length {v} exceeds this platform's usize")))
    }

    /// Reads a declared element count, refusing counts that cannot fit in
    /// the remaining bytes (`elem_size` bytes per element).
    fn get_len(&mut self, elem_size: usize) -> Result<usize, StoreError> {
        let n = self.get_usize()?;
        let need = n
            .checked_mul(elem_size)
            .ok_or_else(|| StoreError::corrupt(format!("element count {n} overflows")))?;
        if need > self.remaining() {
            return Err(StoreError::corrupt(format!(
                "declared {n} elements ({need} bytes) but only {} bytes remain",
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8], StoreError> {
        let n = self.get_len(1)?;
        self.take(n)
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>, StoreError> {
        let n = self.get_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>, StoreError> {
        let n = self.get_len(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>, StoreError> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }

    /// Errors unless the payload was consumed exactly — trailing garbage
    /// means the reader and writer disagree about the layout.
    pub fn expect_end(&self) -> Result<(), StoreError> {
        if self.remaining() != 0 {
            return Err(StoreError::corrupt(format!(
                "{} unread trailing bytes in section payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_usize(42);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_usize().unwrap(), 42);
        r.expect_end().unwrap();
    }

    #[test]
    fn slice_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[u64::MAX, 0]);
        w.put_bytes(b"hello");
        w.put_usizes(&[9, 8]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u64s().unwrap(), vec![u64::MAX, 0]);
        assert_eq!(r.get_bytes().unwrap(), b"hello");
        assert_eq!(r.get_usizes().unwrap(), vec![9, 8]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncated_reads_error() {
        let mut w = ByteWriter::new();
        w.put_u64(1);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn oversized_declared_length_rejected_before_alloc() {
        // length field claims 2^60 u64s — must error, not allocate.
        let mut w = ByteWriter::new();
        w.put_u64(1u64 << 60);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_u64s().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = ByteWriter::new();
        w.put_u32(1);
        w.put_u32(2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let _ = r.get_u32().unwrap();
        assert!(r.expect_end().is_err());
    }
}
