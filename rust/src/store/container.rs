//! The snapshot container: a versioned, sectioned binary file.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset 0   magic      u64   "bSTSNAP1"
//! offset 8   version    u32   FORMAT_VERSION
//! offset 12  n_sections u32
//! offset 16  section table, n_sections × 48 bytes:
//!              name     [u8; 24]  ASCII, zero-padded
//!              offset   u64       absolute, 8-byte aligned
//!              len      u64       payload bytes
//!              checksum u64       FNV-1a 64 over the payload
//! then       payloads, each starting 8-byte aligned (zero padding between)
//! ```
//!
//! Compatibility policy: the magic never changes; `FORMAT_VERSION` bumps on
//! any layout change and readers reject versions they don't know —
//! snapshots are cheap to regenerate from raw sketches, so there is no
//! cross-version migration machinery. Readers accept the whole
//! [`FORMAT_VERSION_V1`]`..=`[`FORMAT_VERSION`] range ([`Snapshot::version`]
//! exposes which format was read so higher layers can gate newer
//! sections); anything newer than [`FORMAT_VERSION`] is rejected
//! outright. Opening validates the table (bounds, alignment, duplicate
//! names) and every section checksum up front, so a truncated or
//! bit-flipped file fails fast with [`StoreError`] instead of surfacing
//! as a confusing payload parse error later.
//!
//! # Mapped-serving contract (v3)
//!
//! [`Snapshot::open_mapped`] serves the container straight from a
//! read-only file mapping instead of an owned buffer. The guarantees that
//! make this zero-copy:
//!
//! * **Alignment.** Section payloads start 8-aligned in the file (as in
//!   every prior version), and — new in v3 — every slice field *inside* a
//!   payload is zero-padded to an 8-byte boundary, so element arrays
//!   (`u32`/`u64` words, postings, rank directories) are correctly
//!   aligned in the mapping and can be borrowed in place
//!   ([`crate::store::PodVec`]). This intra-payload padding is why v3 is
//!   a version bump and not an access-pattern-only change: tag bytes in
//!   the persisted layouts made v2 payload interiors unaligned.
//! * **Validation still runs.** Opening a mapped snapshot checks the
//!   header, table and every checksum, and `read_from` validation is
//!   unchanged — only the payload *copies* are skipped.
//! * **Mapping lifetime.** Section readers hand out `Arc`-shared slices
//!   of the mapping; the file stays mapped until the last borrowing
//!   structure drops. Reload/merge installs a fresh engine (owned or
//!   newly mapped) and the old mapping is released when its last user
//!   dies — queries in flight keep a valid view throughout.
//! * **Fallback.** If mapping fails (or the platform has no `mmap`), the
//!   open falls back to the owned read path; v1/v2 files open mapped too,
//!   but their unaligned interiors fall back to owned copies per field.
//!
//! Mutable state (delta segments, tombstones, id counters) is never
//! served from a mapping — the write path converts to owned on first
//! touch ([`crate::store::PodVec::to_mut`]) and merges rebuild into owned
//! memory.

use super::bytes::Bytes;
use super::mmap::Mmap;
use super::{ByteReader, StoreError};
use std::path::Path;
use std::sync::Arc;

/// File magic: the first 8 bytes of every snapshot.
pub const MAGIC: u64 = u64::from_le_bytes(*b"bSTSNAP1");

/// Current container format version (v3: slice fields inside section
/// payloads are 8-aligned with zero padding, enabling the zero-copy
/// mapped load path).
pub const FORMAT_VERSION: u32 = 3;

/// The PR 4 write-path format: adds the engine sections `rows.N` /
/// `delta.N` / `tombstones.N`. Payload interiors are unpadded.
pub const FORMAT_VERSION_V2: u32 = 2;

/// The PR 2 read-only format: engine snapshots with only `meta` +
/// `shard.N` sections. Still readable; loads as an all-immutable engine.
pub const FORMAT_VERSION_V1: u32 = 1;

/// Maximum section-name length (table entries are fixed-size).
pub const MAX_NAME_LEN: usize = 24;

const TABLE_ENTRY_BYTES: usize = MAX_NAME_LEN + 8 + 8 + 8;
const HEADER_BYTES: usize = 16;

/// FNV-1a 64-bit checksum.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Accumulates named sections and serializes the container.
#[derive(Debug, Default)]
pub struct SnapshotBuilder {
    sections: Vec<(String, Vec<u8>)>,
}

impl SnapshotBuilder {
    pub fn new() -> Self {
        SnapshotBuilder::default()
    }

    /// Adds a section. Names must be non-empty ASCII of at most
    /// [`MAX_NAME_LEN`] bytes and unique within the snapshot.
    pub fn add_section(&mut self, name: &str, payload: Vec<u8>) {
        assert!(
            !name.is_empty() && name.len() <= MAX_NAME_LEN && name.is_ascii(),
            "section name must be 1..={MAX_NAME_LEN} ASCII bytes: {name:?}"
        );
        assert!(
            self.sections.iter().all(|(n, _)| n != name),
            "duplicate section {name:?}"
        );
        self.sections.push((name.to_string(), payload));
    }

    /// Serializes the container to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let table_end = HEADER_BYTES + self.sections.len() * TABLE_ENTRY_BYTES;
        let mut out = Vec::with_capacity(
            table_end
                + self
                    .sections
                    .iter()
                    .map(|(_, p)| p.len().div_ceil(8) * 8)
                    .sum::<usize>(),
        );
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());

        // Section table: offsets assigned sequentially, 8-aligned.
        let mut offset = table_end; // table_end is a multiple of 8
        for (name, payload) in &self.sections {
            let mut name_bytes = [0u8; MAX_NAME_LEN];
            name_bytes[..name.len()].copy_from_slice(name.as_bytes());
            out.extend_from_slice(&name_bytes);
            out.extend_from_slice(&(offset as u64).to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&checksum(payload).to_le_bytes());
            offset += payload.len().div_ceil(8) * 8;
        }

        // Payloads with zero padding up to 8-byte boundaries.
        for (_, payload) in &self.sections {
            out.extend_from_slice(payload);
            let pad = payload.len().div_ceil(8) * 8 - payload.len();
            out.extend_from_slice(&[0u8; 8][..pad]);
        }
        out
    }

    /// Writes the container to `path` crash-atomically (via
    /// `<path>.tmp` + fsync + rename, like the stream writer).
    /// Convenience for small snapshots and tests — the whole file is
    /// assembled in memory first; large multi-section snapshots should
    /// use [`SnapshotStreamWriter`], which buffers only one section at
    /// a time.
    pub fn write_to(&self, path: &Path) -> Result<(), StoreError> {
        let tmp = tmp_path(path);
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        super::sync_parent_dir(path)
    }
}

/// The scratch path a save streams into before renaming over `path`.
fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map_or_else(String::new, |n| n.to_string_lossy().into_owned());
    name.push_str(".tmp");
    path.with_file_name(name)
}

/// Incremental snapshot writer: sections stream to disk as they are
/// produced (payload + padding written immediately, checksummed on the
/// way through) and the table — whose entries are only known once every
/// payload has been sized — is patched in by seeking back at
/// [`SnapshotStreamWriter::finish`]. Peak memory is one section's
/// payload, not the whole container; `Engine::save` uses this so a
/// multi-GiB engine never holds a second full copy of itself while
/// persisting.
///
/// The section count is fixed at creation (the table is laid out before
/// payloads); `finish` errors unless exactly that many were added.
///
/// Saves are crash-atomic: bytes stream into `<path>.tmp` and
/// [`SnapshotStreamWriter::finish`] fsyncs the scratch file, renames it
/// over `path`, and fsyncs the directory — a crash at any earlier point
/// leaves the previous snapshot untouched and loadable, never a
/// half-written container under the real name.
pub struct SnapshotStreamWriter {
    file: std::io::BufWriter<std::fs::File>,
    /// Final destination; bytes stream into [`SnapshotStreamWriter::tmp`]
    /// until `finish` renames.
    path: std::path::PathBuf,
    /// The `<path>.tmp` scratch file receiving the stream.
    tmp: std::path::PathBuf,
    /// `(name, offset, len, checksum)` per written section.
    table: Vec<(String, u64, u64, u64)>,
    n_sections: usize,
    offset: u64,
}

impl SnapshotStreamWriter {
    /// Creates the scratch file (`<path>.tmp`) and reserves header +
    /// table space for exactly `n_sections` sections.
    pub fn create(path: &Path, n_sections: usize) -> Result<Self, StoreError> {
        use std::io::Write;
        let tmp = tmp_path(path);
        let mut file = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        file.write_all(&MAGIC.to_le_bytes())?;
        file.write_all(&FORMAT_VERSION.to_le_bytes())?;
        file.write_all(&(n_sections as u32).to_le_bytes())?;
        // Placeholder table, patched by finish().
        let zeros = [0u8; TABLE_ENTRY_BYTES];
        for _ in 0..n_sections {
            file.write_all(&zeros)?;
        }
        let offset = (HEADER_BYTES + n_sections * TABLE_ENTRY_BYTES) as u64;
        Ok(SnapshotStreamWriter {
            file,
            path: path.to_path_buf(),
            tmp,
            table: Vec::with_capacity(n_sections),
            n_sections,
            offset,
        })
    }

    /// Streams one section's payload (plus alignment padding) to disk.
    pub fn add_section(&mut self, name: &str, payload: &[u8]) -> Result<(), StoreError> {
        use std::io::Write;
        // Mid-save fault site: the crash-atomicity tests kill or fail a
        // save here, between sections, and assert the previous snapshot
        // still loads.
        if crate::util::failpoint::check("save.section", &self.tmp.to_string_lossy()).is_some() {
            return Err(StoreError::Io(crate::util::failpoint::io_error("save.section")));
        }
        assert!(
            !name.is_empty() && name.len() <= MAX_NAME_LEN && name.is_ascii(),
            "section name must be 1..={MAX_NAME_LEN} ASCII bytes: {name:?}"
        );
        assert!(
            self.table.len() < self.n_sections,
            "snapshot declared {} sections; {name:?} is one too many",
            self.n_sections
        );
        assert!(
            self.table.iter().all(|(n, ..)| n != name),
            "duplicate section {name:?}"
        );
        self.file.write_all(payload)?;
        let pad = payload.len().div_ceil(8) * 8 - payload.len();
        self.file.write_all(&[0u8; 8][..pad])?;
        self.table
            .push((name.to_string(), self.offset, payload.len() as u64, checksum(payload)));
        self.offset += (payload.len() + pad) as u64;
        Ok(())
    }

    /// Seeks back and writes the real section table, fsyncs the scratch
    /// file, renames it over the destination, and fsyncs the directory.
    /// The snapshot only ever appears under its real name complete.
    pub fn finish(mut self) -> Result<(), StoreError> {
        use std::io::{Seek, SeekFrom, Write};
        if self.table.len() != self.n_sections {
            return Err(StoreError::Corrupt(format!(
                "snapshot declared {} sections but {} were written",
                self.n_sections,
                self.table.len()
            )));
        }
        self.file.flush()?;
        self.file.seek(SeekFrom::Start(HEADER_BYTES as u64))?;
        for (name, offset, len, sum) in &self.table {
            let mut name_bytes = [0u8; MAX_NAME_LEN];
            name_bytes[..name.len()].copy_from_slice(name.as_bytes());
            self.file.write_all(&name_bytes)?;
            self.file.write_all(&offset.to_le_bytes())?;
            self.file.write_all(&len.to_le_bytes())?;
            self.file.write_all(&sum.to_le_bytes())?;
        }
        self.file.flush()?;
        self.file.get_ref().sync_all()?;
        std::fs::rename(&self.tmp, &self.path)?;
        super::sync_parent_dir(&self.path)
    }
}

/// A validated, loaded snapshot. The backing region is either an owned
/// heap buffer ([`Snapshot::open`] / [`Snapshot::from_bytes`]) or a
/// read-only file mapping ([`Snapshot::open_mapped`]); section readers
/// over a mapped snapshot hand out zero-copy borrows of the mapping.
pub struct Snapshot {
    bytes: Bytes,
    /// `(name, payload start, payload len)` per section.
    sections: Vec<(String, usize, usize)>,
    /// Format version the file declared (v1..=v3).
    version: u32,
}

impl Snapshot {
    /// Parses and fully validates an owned container (header, table
    /// bounds and alignment, section checksums).
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self, StoreError> {
        Snapshot::from_region(Bytes::from_vec(bytes))
    }

    /// Parses and fully validates a container over any shared region.
    fn from_region(bytes: Bytes) -> Result<Self, StoreError> {
        if bytes.len() < HEADER_BYTES {
            return Err(StoreError::corrupt(format!(
                "file too short for a snapshot header: {} bytes",
                bytes.len()
            )));
        }
        let magic = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        if magic != MAGIC {
            return Err(StoreError::BadMagic(magic));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if !(FORMAT_VERSION_V1..=FORMAT_VERSION).contains(&version) {
            return Err(StoreError::UnsupportedVersion(version));
        }
        let n_sections = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let table_end = HEADER_BYTES
            .checked_add(n_sections.checked_mul(TABLE_ENTRY_BYTES).ok_or_else(|| {
                StoreError::corrupt(format!("section count {n_sections} overflows"))
            })?)
            .ok_or_else(|| StoreError::corrupt("section table overflows".into()))?;
        if table_end > bytes.len() {
            return Err(StoreError::corrupt(format!(
                "truncated section table: need {table_end} bytes, file has {}",
                bytes.len()
            )));
        }

        let mut sections: Vec<(String, usize, usize)> = Vec::with_capacity(n_sections);
        for s in 0..n_sections {
            let e = HEADER_BYTES + s * TABLE_ENTRY_BYTES;
            let raw_name = &bytes[e..e + MAX_NAME_LEN];
            let name_len = raw_name.iter().position(|&b| b == 0).unwrap_or(MAX_NAME_LEN);
            let name = std::str::from_utf8(&raw_name[..name_len])
                .map_err(|_| StoreError::corrupt(format!("section {s}: non-UTF8 name")))?
                .to_string();
            if name.is_empty() || raw_name[name_len..].iter().any(|&b| b != 0) {
                return Err(StoreError::corrupt(format!("section {s}: malformed name")));
            }
            let offset = u64::from_le_bytes(
                bytes[e + MAX_NAME_LEN..e + MAX_NAME_LEN + 8].try_into().unwrap(),
            );
            let len = u64::from_le_bytes(
                bytes[e + MAX_NAME_LEN + 8..e + MAX_NAME_LEN + 16].try_into().unwrap(),
            );
            let sum = u64::from_le_bytes(
                bytes[e + MAX_NAME_LEN + 16..e + MAX_NAME_LEN + 24].try_into().unwrap(),
            );
            let offset = usize::try_from(offset)
                .map_err(|_| StoreError::corrupt(format!("section {name}: bad offset")))?;
            let len = usize::try_from(len)
                .map_err(|_| StoreError::corrupt(format!("section {name}: bad length")))?;
            let end = offset.checked_add(len).ok_or_else(|| {
                StoreError::corrupt(format!("section {name}: offset+len overflows"))
            })?;
            if offset % 8 != 0 || offset < table_end || end > bytes.len() {
                return Err(StoreError::corrupt(format!(
                    "section {name}: range {offset}..{end} invalid (file len {})",
                    bytes.len()
                )));
            }
            if sections.iter().any(|(n, _, _)| *n == name) {
                return Err(StoreError::corrupt(format!("duplicate section {name}")));
            }
            if checksum(&bytes[offset..end]) != sum {
                return Err(StoreError::corrupt(format!("section {name}: checksum mismatch")));
            }
            sections.push((name, offset, len));
        }
        Ok(Snapshot { bytes, sections, version })
    }

    /// Reads and validates a snapshot file into an owned buffer.
    pub fn open(path: &Path) -> Result<Self, StoreError> {
        Snapshot::from_bytes(std::fs::read(path)?)
    }

    /// Maps and validates a snapshot file — the zero-copy serving mode.
    /// Table and checksum validation run exactly as in [`Snapshot::open`]
    /// (one sequential read through the page cache), but no payload bytes
    /// are copied to the heap; section readers borrow the mapping. Only
    /// *mapping* failures (unsupported platform, resource limits) fall
    /// back to the owned read path — validation errors propagate.
    pub fn open_mapped(path: &Path) -> Result<Self, StoreError> {
        let file = std::fs::File::open(path)?;
        match Mmap::map(&file) {
            Ok(m) => Snapshot::from_region(Bytes::from_map(Arc::new(m))),
            Err(_) => Snapshot::open(path),
        }
    }

    /// Whether this snapshot serves from a file mapping.
    pub fn is_mapped(&self) -> bool {
        self.bytes.is_mapped()
    }

    /// The backing file mapping of a mapped snapshot (`None` for owned
    /// loads). The engine keeps this alive to probe page residency.
    pub fn mapping(&self) -> Option<&std::sync::Arc<Mmap>> {
        self.bytes.mapping()
    }

    /// Format version the file declared ([`FORMAT_VERSION_V1`]
    /// `..=` [`FORMAT_VERSION`]).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Names of all sections, in file order.
    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.iter().map(|(n, _, _)| n.as_str())
    }

    pub fn has_section(&self, name: &str) -> bool {
        self.sections.iter().any(|(n, _, _)| n == name)
    }

    /// Byte ranges of all sections as `(name, file_offset, len)`, in
    /// file order. Offsets address the whole file (header included), so
    /// a mapped caller can aim page-level advice (`madvise`) at
    /// individual sections without parsing them.
    pub fn section_ranges(&self) -> impl Iterator<Item = (&str, usize, usize)> {
        self.sections.iter().map(|(n, off, len)| (n.as_str(), *off, *len))
    }

    /// A checked reader over the named section's payload. The reader is
    /// format-aware (v3 payload interiors are aligned, older ones are
    /// not) and, on a mapped snapshot, carries the backing region so
    /// `*_ref` reads borrow the mapping instead of copying.
    pub fn section(&self, name: &str) -> Result<ByteReader<'_>, StoreError> {
        let (_, off, len) = self
            .sections
            .iter()
            .find(|(n, _, _)| n == name)
            .ok_or_else(|| StoreError::MissingSection(name.to_string()))?;
        let padded = self.version > FORMAT_VERSION_V2;
        let backing = if self.bytes.is_mapped() {
            Some(self.bytes.slice(*off..*off + *len))
        } else {
            None
        };
        Ok(ByteReader::with_backing(&self.bytes[*off..*off + *len], backing, padded))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SnapshotBuilder {
        let mut b = SnapshotBuilder::new();
        b.add_section("meta", vec![1, 2, 3]);
        b.add_section("shard.0", (0u8..100).collect());
        b.add_section("shard.1", Vec::new());
        b
    }

    #[test]
    fn roundtrip() {
        let bytes = sample().to_bytes();
        let snap = Snapshot::from_bytes(bytes).unwrap();
        assert_eq!(
            snap.section_names().collect::<Vec<_>>(),
            vec!["meta", "shard.0", "shard.1"]
        );
        let mut r = snap.section("meta").unwrap();
        assert_eq!(r.get_u8().unwrap(), 1);
        let mut r = snap.section("shard.0").unwrap();
        assert_eq!(r.remaining(), 100);
        for i in 0u8..100 {
            assert_eq!(r.get_u8().unwrap(), i);
        }
        r.expect_end().unwrap();
        assert_eq!(snap.section("shard.1").unwrap().remaining(), 0);
        assert!(snap.has_section("meta"));
        assert!(!snap.has_section("nope"));
    }

    #[test]
    fn missing_section_is_err() {
        let snap = Snapshot::from_bytes(sample().to_bytes()).unwrap();
        assert!(matches!(
            snap.section("absent"),
            Err(StoreError::MissingSection(_))
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[0] ^= 0xFF;
        assert!(matches!(
            Snapshot::from_bytes(bytes),
            Err(StoreError::BadMagic(_))
        ));
    }

    #[test]
    fn wrong_version_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[8] = 99;
        assert!(matches!(
            Snapshot::from_bytes(bytes),
            Err(StoreError::UnsupportedVersion(_))
        ));
    }

    #[test]
    fn v1_files_still_open() {
        // The write-path bump (v2) is additive: a v1 file (same table
        // layout, fewer section kinds) must keep loading, and report its
        // version so higher layers can gate the v2-only sections.
        let mut bytes = sample().to_bytes();
        assert_eq!(Snapshot::from_bytes(bytes.clone()).unwrap().version(), FORMAT_VERSION);
        bytes[8..12].copy_from_slice(&FORMAT_VERSION_V1.to_le_bytes());
        let snap = Snapshot::from_bytes(bytes).unwrap();
        assert_eq!(snap.version(), FORMAT_VERSION_V1);
        assert_eq!(snap.section_names().count(), 3);
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().to_bytes();
        for cut in [0, 10, 17, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                Snapshot::from_bytes(bytes[..cut].to_vec()).is_err(),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn payload_corruption_caught_by_checksum() {
        let bytes = sample().to_bytes();
        let snap = Snapshot::from_bytes(bytes.clone()).unwrap();
        let (_, off, _) = snap.sections[1];
        let mut bad = bytes;
        bad[off + 5] ^= 0x40;
        assert!(matches!(
            Snapshot::from_bytes(bad),
            Err(StoreError::Corrupt(_))
        ));
    }

    #[test]
    fn streamed_file_matches_in_memory_assembly() {
        let b = sample();
        let dir = std::env::temp_dir().join("bst_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.snap");
        let mut w = SnapshotStreamWriter::create(&path, 3).unwrap();
        w.add_section("meta", &[1, 2, 3]).unwrap();
        w.add_section("shard.0", &(0u8..100).collect::<Vec<u8>>()).unwrap();
        w.add_section("shard.1", &[]).unwrap();
        w.finish().unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            b.to_bytes(),
            "streamed bytes must equal the in-memory assembly"
        );
        let snap = Snapshot::open(&path).unwrap();
        assert_eq!(snap.section_names().count(), 3);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stream_writer_enforces_section_count() {
        let dir = std::env::temp_dir().join("bst_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("short.snap");
        let mut w = SnapshotStreamWriter::create(&path, 2).unwrap();
        w.add_section("only", &[9]).unwrap();
        assert!(w.finish().is_err(), "missing section must fail finish");
        // The failed save never appeared under the real name — only the
        // scratch file exists.
        assert!(!path.exists(), "failed finish must not install the snapshot");
        std::fs::remove_file(tmp_path(&path)).unwrap();
    }

    #[test]
    fn crashed_save_preserves_previous_snapshot() {
        let dir = std::env::temp_dir().join("bst_container_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("atomic.snap");
        let good = sample();
        good.write_to(&path).unwrap();

        // A save that dies between sections (injected I/O failure at
        // the `save.section` failpoint) must leave the old file intact.
        let mut w = SnapshotStreamWriter::create(&path, 3).unwrap();
        w.add_section("meta", &[9, 9, 9]).unwrap();
        crate::util::failpoint::arm_scoped(
            "save.section",
            "bst_container_atomic_test",
            0,
            1,
            crate::util::failpoint::Action::Error,
        );
        let err = w.add_section("shard.0", &[1]);
        crate::util::failpoint::clear("save.section");
        assert!(err.is_err(), "armed failpoint must fail the section write");
        drop(w);

        let snap = Snapshot::open(&path).unwrap();
        assert_eq!(
            snap.section_names().collect::<Vec<_>>(),
            good.sections.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            "previous snapshot must survive a mid-save crash"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let b = SnapshotBuilder::new();
        let snap = Snapshot::from_bytes(b.to_bytes()).unwrap();
        assert_eq!(snap.section_names().count(), 0);
    }

    #[test]
    fn alignment_of_all_sections() {
        let bytes = sample().to_bytes();
        let snap = Snapshot::from_bytes(bytes).unwrap();
        for (_, off, _) in &snap.sections {
            assert_eq!(off % 8, 0);
        }
    }

    #[test]
    fn open_mapped_matches_owned_open() {
        use crate::store::bytes::ByteWriter;
        let mut b = SnapshotBuilder::new();
        let mut w = ByteWriter::new();
        w.put_u8(5);
        w.put_u64s(&[1, 2, 3]);
        w.put_u32s(&[7, 8]);
        b.add_section("payload", w.into_bytes());
        b.add_section("empty", Vec::new());
        let dir = std::env::temp_dir().join("bst_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mapped.snap");
        b.write_to(&path).unwrap();

        let owned = Snapshot::open(&path).unwrap();
        let mapped = Snapshot::open_mapped(&path).unwrap();
        assert!(!owned.is_mapped());
        assert_eq!(owned.version(), mapped.version());
        assert_eq!(
            owned.section_names().collect::<Vec<_>>(),
            mapped.section_names().collect::<Vec<_>>()
        );
        for snap in [&owned, &mapped] {
            let mut r = snap.section("payload").unwrap();
            assert_eq!(r.get_u8().unwrap(), 5);
            let words = r.get_u64s_ref().unwrap();
            let ids = r.get_u32s_ref().unwrap();
            r.expect_end().unwrap();
            assert_eq!(&words[..], &[1, 2, 3]);
            assert_eq!(&ids[..], &[7, 8]);
            // Zero-copy on the mapped side (mappings are page-aligned,
            // so the aligned v3 interior always borrows), owned copies.
            assert_eq!(words.is_mapped(), snap.is_mapped());
            assert_eq!(ids.is_mapped(), snap.is_mapped());
            assert_eq!(snap.section("empty").unwrap().remaining(), 0);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v2_sections_read_with_legacy_layout() {
        use crate::store::bytes::ByteWriter;
        // A v2 file's payload interiors are unpadded; the section reader
        // must decode them with padding disabled.
        let mut w = ByteWriter::legacy();
        w.put_u8(9);
        w.put_u32s(&[4, 5, 6]);
        let mut b = SnapshotBuilder::new();
        b.add_section("legacy", w.into_bytes());
        let mut bytes = b.to_bytes();
        bytes[8..12].copy_from_slice(&FORMAT_VERSION_V2.to_le_bytes());
        let snap = Snapshot::from_bytes(bytes).unwrap();
        assert_eq!(snap.version(), FORMAT_VERSION_V2);
        let mut r = snap.section("legacy").unwrap();
        assert_eq!(r.get_u8().unwrap(), 9);
        assert_eq!(r.get_u32s().unwrap(), vec![4, 5, 6]);
        r.expect_end().unwrap();
    }
}
