//! Per-engine write-ahead log: the durability layer behind every
//! acknowledged insert/delete.
//!
//! Snapshots persist the engine wholesale but only on explicit `save`;
//! everything acknowledged since lives in in-memory delta segments and
//! tombstone sets. The WAL closes that window: each write appends one
//! length-prefixed, FNV-1a-checksummed record (the same checksum
//! convention as the snapshot container) *before* the engine
//! acknowledges it, and `Engine::load` replays records past the
//! snapshot's id high-water mark on the next start.
//!
//! ## Record frame
//!
//! ```text
//! [u32 payload len LE] [u64 FNV-1a(payload) LE] [payload]
//! ```
//!
//! Payloads use the compact legacy byte layout (`ByteWriter::legacy`):
//! a `u8` kind tag, then per-kind fields — inserts carry the first
//! global id, the row count, and the flattened row characters; deletes
//! carry one id; merge markers carry nothing (they only record that the
//! in-memory segments were reorganized; replay ignores them).
//!
//! ## Torn tails
//!
//! A crash can leave a partial record at the very end of the newest
//! segment. Opening the log truncates at the first frame that is
//! incomplete, has an impossible length, fails its checksum, or fails
//! to parse — that prefix property (every byte-prefix of a WAL replays
//! cleanly up to a record boundary) is what the `prop_wal` suite
//! enforces. Records never straddle that point because an append that
//! errors mid-write erases its partial bytes (or, if even the erase
//! fails, permanently poisons the log so nothing further is
//! acknowledged).
//!
//! ## Segments and rotation
//!
//! The log is a sequence of files `{base}.{seq}`. `Engine::save`
//! rotates under the insert lock: a fresh segment opens *before* the
//! snapshot is written (`rotate_begin`) and the old segments are
//! deleted only *after* the snapshot has durably renamed into place
//! (`rotate_commit`). A crash between the two leaves extra old
//! segments whose records are all below the new snapshot's high-water
//! mark — replay skips them idempotently.
//!
//! ## Sync policies
//!
//! * [`WalSync::Always`] — fsync every record before acknowledging:
//!   an acknowledged write survives kill -9 and power loss.
//! * [`WalSync::Batch`] — write-through, fsync every
//!   [`BATCH_SYNC_BYTES`]: an OS crash can lose the unsynced suffix of
//!   acknowledged writes; a process kill cannot (the kernel holds the
//!   written bytes).
//! * [`WalSync::Off`] — never fsync: same process-kill guarantee as
//!   `Batch`, no protection against OS/power failure.

use super::container::checksum;
use super::sync_parent_dir as sync_dir;
use super::{ByteReader, ByteWriter, StoreError};
use crate::util::failpoint;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Fsync cadence under [`WalSync::Batch`]: bytes written since the last
/// sync before the next append forces one.
pub const BATCH_SYNC_BYTES: u64 = 256 * 1024;

/// Frame header size: u32 payload length + u64 payload checksum.
const FRAME_HEADER: usize = 12;

/// Upper bound on a single record payload (a frame declaring more is
/// treated as torn, not allocated).
const MAX_RECORD_BYTES: u32 = 1 << 30;

/// Durability policy for WAL appends (`--wal-sync`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalSync {
    /// Fsync before every acknowledgement.
    Always,
    /// Fsync every [`BATCH_SYNC_BYTES`] of appended records.
    Batch,
    /// Never fsync (page cache only).
    Off,
}

impl WalSync {
    /// Parses the CLI spelling (`always` / `batch` / `off`).
    pub fn parse(s: &str) -> Option<WalSync> {
        match s {
            "always" => Some(WalSync::Always),
            "batch" => Some(WalSync::Batch),
            "off" => Some(WalSync::Off),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WalSync::Always => "always",
            WalSync::Batch => "batch",
            WalSync::Off => "off",
        }
    }
}

/// One logical WAL record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// `n` rows inserted with contiguous global ids starting at
    /// `start_id`; `chars` is the row characters flattened in id order
    /// (`n * L` bytes — `L` is implied by the engine replaying it).
    Insert { start_id: u32, n: u32, chars: Vec<u8> },
    /// One tombstoned global id.
    Delete { id: u32 },
    /// A background/forced merge folded delta rows into the base.
    /// Replay ignores it (merges don't change answers); it exists so an
    /// operator reading the log can correlate it with serving history.
    MergeMarker,
}

const KIND_INSERT: u8 = 1;
const KIND_DELETE: u8 = 2;
const KIND_MERGE: u8 = 3;

impl WalRecord {
    fn payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::legacy();
        match self {
            WalRecord::Insert { start_id, n, chars } => {
                w.put_u8(KIND_INSERT);
                w.put_u32(*start_id);
                w.put_u32(*n);
                w.put_bytes(chars);
            }
            WalRecord::Delete { id } => {
                w.put_u8(KIND_DELETE);
                w.put_u32(*id);
            }
            WalRecord::MergeMarker => w.put_u8(KIND_MERGE),
        }
        w.into_bytes()
    }

    fn parse(payload: &[u8]) -> Result<WalRecord, StoreError> {
        let mut r = ByteReader::legacy(payload);
        let rec = match r.get_u8()? {
            KIND_INSERT => {
                let start_id = r.get_u32()?;
                let n = r.get_u32()?;
                let chars = r.get_bytes()?.to_vec();
                if n as usize != 0 && chars.len() % n as usize != 0 {
                    return Err(StoreError::corrupt(format!(
                        "wal insert record: {} chars not divisible by {n} rows",
                        chars.len()
                    )));
                }
                WalRecord::Insert { start_id, n, chars }
            }
            KIND_DELETE => WalRecord::Delete { id: r.get_u32()? },
            KIND_MERGE => WalRecord::MergeMarker,
            k => {
                return Err(StoreError::corrupt(format!("wal record: unknown kind {k}")));
            }
        };
        r.expect_end()?;
        Ok(rec)
    }

    /// The full on-disk frame: header + payload.
    fn frame(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&checksum(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// What [`Wal::open`] found and did.
#[derive(Debug, Default)]
pub struct WalOpenReport {
    /// Segment files scanned.
    pub segments: usize,
    /// Valid records recovered (all segments, in order).
    pub records: usize,
    /// Torn/corrupt bytes truncated off the newest segment.
    pub truncated_bytes: u64,
}

/// An open, appendable write-ahead log.
pub struct Wal {
    base: PathBuf,
    /// `base` as a display string — the failpoint context, so tests
    /// scope injected faults to their own log.
    ctx: String,
    file: File,
    /// Sequence number of the segment receiving appends.
    seq: u64,
    /// Valid length of the current segment.
    len: u64,
    sync: WalSync,
    /// Bytes appended since the last fsync ([`WalSync::Batch`]).
    pending: u64,
    /// Set when a failed append could not erase its partial bytes: the
    /// tail is untrustworthy, so every further append is refused.
    broken: bool,
}

impl Wal {
    /// Opens (or creates) the log at `base`, recovering every valid
    /// record from all segments in sequence order and truncating the
    /// torn tail of the newest segment. Appends resume at the
    /// truncation point.
    pub fn open(
        base: &Path,
        sync: WalSync,
    ) -> Result<(Wal, Vec<WalRecord>, WalOpenReport), StoreError> {
        let seqs = list_segments(base)?;
        let mut records = Vec::new();
        let mut report = WalOpenReport { segments: seqs.len().max(1), ..Default::default() };
        let mut last_valid = 0u64;
        for (i, &seq) in seqs.iter().enumerate() {
            let path = segment_path(base, seq);
            let bytes = std::fs::read(&path)?;
            let (recs, valid) = scan_segment(&bytes);
            records.extend(recs);
            if i + 1 == seqs.len() {
                // Newest segment: physically truncate the torn tail so
                // appends land on a record boundary.
                if (valid as u64) < bytes.len() as u64 {
                    report.truncated_bytes = bytes.len() as u64 - valid as u64;
                    let f = OpenOptions::new().write(true).open(&path)?;
                    f.set_len(valid as u64)?;
                    f.sync_data()?;
                }
                last_valid = valid as u64;
            }
        }
        report.records = records.len();
        let seq = seqs.last().copied().unwrap_or(0);
        let path = segment_path(base, seq);
        let created = !path.exists();
        let file = OpenOptions::new().append(true).create(true).open(&path)?;
        if created {
            sync_dir(base)?;
        }
        let wal = Wal {
            base: base.to_path_buf(),
            ctx: base.to_string_lossy().into_owned(),
            file,
            seq,
            len: last_valid,
            sync,
            pending: 0,
            broken: false,
        };
        Ok((wal, records, report))
    }

    /// The segment-base path this log writes under.
    pub fn base(&self) -> &Path {
        &self.base
    }

    /// Appends one record, durable per the sync policy, before the
    /// caller acknowledges the write. On `Err` the record is guaranteed
    /// *not* to be replayed later: partial bytes are erased, or the log
    /// is poisoned so no later record can land after a torn one.
    pub fn append(&mut self, rec: &WalRecord) -> Result<(), StoreError> {
        if self.broken {
            return Err(StoreError::corrupt(
                "wal is poisoned after a failed append; restart to recover".into(),
            ));
        }
        let frame = rec.frame();

        // Failpoint: simulate power loss mid-append — some prefix of
        // the frame reaches disk and the process is assumed dead, so no
        // cleanup runs. The log is poisoned to stop this process from
        // writing anything after the torn bytes.
        if let Some(failpoint::Action::ShortWrite(k)) =
            failpoint::check("wal.append.short", &self.ctx)
        {
            let k = k.min(frame.len());
            let _ = self.file.write_all(&frame[..k]);
            let _ = self.file.sync_data();
            self.broken = true;
            return Err(StoreError::Io(failpoint::io_error("wal.append.short")));
        }

        match self.write_durable(&frame) {
            Ok(()) => {
                self.len += frame.len() as u64;
                Ok(())
            }
            Err(e) => {
                // Erase whatever partially landed so the *next* append
                // (which may reuse the rolled-back ids) can never sit
                // after a torn record that replay would misread.
                if self.file.set_len(self.len).is_err() {
                    self.broken = true;
                }
                self.pending = 0;
                Err(e)
            }
        }
    }

    fn write_durable(&mut self, frame: &[u8]) -> Result<(), StoreError> {
        self.file.write_all(frame)?;
        if failpoint::check("wal.sync", &self.ctx) == Some(failpoint::Action::Error) {
            return Err(StoreError::Io(failpoint::io_error("wal.sync")));
        }
        match self.sync {
            WalSync::Always => self.file.sync_data()?,
            WalSync::Batch => {
                self.pending += frame.len() as u64;
                if self.pending >= BATCH_SYNC_BYTES {
                    self.file.sync_data()?;
                    self.pending = 0;
                }
            }
            WalSync::Off => {}
        }
        Ok(())
    }

    /// Forces any deferred fsync ([`WalSync::Batch`]) to disk now.
    pub fn sync(&mut self) -> Result<(), StoreError> {
        if self.pending > 0 {
            self.file.sync_data()?;
            self.pending = 0;
        }
        Ok(())
    }

    /// Opens the next segment; subsequent appends go there. Called
    /// under the insert lock *before* a snapshot is written, so every
    /// record covering post-snapshot writes lives in the new segment.
    /// Old segments stay on disk until [`Wal::rotate_commit`].
    pub fn rotate_begin(&mut self) -> Result<(), StoreError> {
        self.sync()?;
        let seq = self.seq + 1;
        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(segment_path(&self.base, seq))?;
        sync_dir(&self.base)?;
        self.file = file;
        self.seq = seq;
        self.len = 0;
        self.pending = 0;
        self.broken = false;
        Ok(())
    }

    /// Deletes every segment older than the current one. Called only
    /// after the snapshot covering them has durably renamed into
    /// place; a crash before this leaves old segments whose records
    /// replay idempotently (all below the snapshot's high-water mark).
    pub fn rotate_commit(&mut self) -> Result<(), StoreError> {
        let mut removed = false;
        for seq in list_segments(&self.base)? {
            if seq < self.seq {
                std::fs::remove_file(segment_path(&self.base, seq))?;
                removed = true;
            }
        }
        if removed {
            sync_dir(&self.base)?;
        }
        Ok(())
    }

    /// The current append frontier: every record this log has accepted
    /// lives strictly before this cursor, and a [`fetch_frames`] from it
    /// returns only records appended afterwards. `Engine::save` hands
    /// this to replication bootstrap so a follower can tail from the
    /// exact position its snapshot covers.
    pub fn cursor(&self) -> WalCursor {
        WalCursor { seq: self.seq, off: self.len }
    }
}

/// A position in the segmented log: segment sequence number plus byte
/// offset within that segment. Always sits on a frame boundary (the
/// fetch API only ever hands out frame-aligned cursors; a misaligned
/// cursor is detected by checksum and reported as a gap).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalCursor {
    pub seq: u64,
    pub off: u64,
}

/// One fetched span of raw frames, wire-ready: the bytes are exactly as
/// they sit on disk (length-prefixed, checksummed), so the receiver
/// re-verifies integrity with [`scan_frames`] before applying.
pub struct WalChunk {
    /// Concatenated raw frames (possibly spanning segment boundaries).
    pub frames: Vec<u8>,
    /// Number of whole records in `frames`.
    pub records: usize,
    /// Where the next fetch should resume.
    pub next: WalCursor,
}

/// Outcome of a cursor fetch.
pub enum WalFetch {
    /// Frames from the cursor forward (empty = caught up).
    Chunk(WalChunk),
    /// The cursor's segment no longer exists (rotated away) or the
    /// offset does not sit on a frame boundary: the tail from this
    /// position is unrecoverable and the reader must re-bootstrap from
    /// a snapshot.
    Gap,
}

/// Read-only cursor fetch: returns up to `max_bytes` of raw frames
/// starting at `from`, crossing segment boundaries, always whole frames
/// and always at least one when any is available (so a single oversized
/// record cannot wedge a small budget). Never writes; safe to run
/// concurrently with an appender — the scan stops at the last complete
/// frame, which only ever moves forward.
pub fn fetch_frames(
    base: &Path,
    from: WalCursor,
    max_bytes: usize,
) -> Result<WalFetch, StoreError> {
    let seqs = list_segments(base)?;
    if seqs.is_empty() {
        // No log yet: the origin cursor is trivially caught up;
        // anything else claims history that never existed here.
        return Ok(if from == WalCursor::default() {
            WalFetch::Chunk(WalChunk { frames: Vec::new(), records: 0, next: from })
        } else {
            WalFetch::Gap
        });
    }
    let Some(start) = seqs.iter().position(|&s| s == from.seq) else {
        return Ok(WalFetch::Gap);
    };
    let mut frames = Vec::new();
    let mut records = 0usize;
    let mut next = from;
    for (i, &seq) in seqs[start..].iter().enumerate() {
        let bytes = std::fs::read(segment_path(base, seq))?;
        let (_, valid) = scan_segment(&bytes);
        let off = if i == 0 { from.off as usize } else { 0 };
        if off > valid {
            return Ok(WalFetch::Gap);
        }
        let region = &bytes[off..valid];
        let (consumed, n) =
            take_frames(region, max_bytes.saturating_sub(frames.len()), frames.is_empty());
        if consumed == 0 && !region.is_empty() && frames.is_empty() {
            // A non-empty region whose first frame fails to parse:
            // the cursor is not on a frame boundary.
            return Ok(WalFetch::Gap);
        }
        frames.extend_from_slice(&region[..consumed]);
        records += n;
        next = WalCursor { seq, off: (off + consumed) as u64 };
        if consumed < region.len() {
            break; // budget exhausted mid-segment
        }
        match seqs.get(start + i + 1) {
            // This segment is drained and a newer one exists: the next
            // fetch starts there.
            Some(&later) => next = WalCursor { seq: later, off: 0 },
            None => break, // at the write frontier
        }
    }
    Ok(WalFetch::Chunk(WalChunk { frames, records, next }))
}

/// Takes whole valid frames from the start of `bytes` up to `budget`
/// total bytes; `take_one` forces the first frame through regardless of
/// budget. Returns (bytes consumed, frames taken).
fn take_frames(bytes: &[u8], budget: usize, take_one: bool) -> (usize, usize) {
    let mut pos = 0usize;
    let mut n = 0usize;
    while bytes.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_RECORD_BYTES || (len as usize) > bytes.len() - pos - FRAME_HEADER {
            break;
        }
        let end = pos + FRAME_HEADER + len as usize;
        let payload = &bytes[pos + FRAME_HEADER..end];
        if checksum(payload) != sum || WalRecord::parse(payload).is_err() {
            break;
        }
        if end > budget && !(take_one && n == 0) {
            break;
        }
        pos = end;
        n += 1;
    }
    (pos, n)
}

/// Parses a span of raw frames (as produced by [`fetch_frames`]) back
/// into records, verifying every length and checksum. Returns the
/// records and the clean-prefix length — a receiver must treat anything
/// short of `bytes.len()` as transport corruption and re-fetch.
pub fn scan_frames(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    scan_segment(bytes)
}

/// Read-only scan of every valid record under `base` (all segments, in
/// order), tolerating a torn tail. Used by shard rebuild, which replays
/// while the engine's own `Wal` handle keeps appending — the scan never
/// truncates or otherwise writes.
pub fn read_records(base: &Path) -> Result<Vec<WalRecord>, StoreError> {
    let mut records = Vec::new();
    for seq in list_segments(base)? {
        let bytes = std::fs::read(segment_path(base, seq))?;
        let (recs, _) = scan_segment(&bytes);
        records.extend(recs);
    }
    Ok(records)
}

/// Parses frames from the start of `bytes`, stopping at the first torn
/// or corrupt frame. Returns the records and the clean-prefix length.
fn scan_segment(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_RECORD_BYTES || (len as usize) > bytes.len() - pos - FRAME_HEADER {
            break;
        }
        let payload = &bytes[pos + FRAME_HEADER..pos + FRAME_HEADER + len as usize];
        if checksum(payload) != sum {
            break;
        }
        match WalRecord::parse(payload) {
            Ok(rec) => records.push(rec),
            Err(_) => break,
        }
        pos += FRAME_HEADER + len as usize;
    }
    (records, pos)
}

/// The path of segment `seq`: `{base}.{seq}`.
fn segment_path(base: &Path, seq: u64) -> PathBuf {
    let mut name = base.file_name().map_or_else(String::new, |n| n.to_string_lossy().into_owned());
    name.push('.');
    name.push_str(&seq.to_string());
    base.with_file_name(name)
}

/// Existing segment sequence numbers under `base`, ascending.
fn list_segments(base: &Path) -> Result<Vec<u64>, StoreError> {
    let dir = base.parent().filter(|p| !p.as_os_str().is_empty()).unwrap_or(Path::new("."));
    let stem = match base.file_name() {
        Some(n) => {
            let mut s = n.to_string_lossy().into_owned();
            s.push('.');
            s
        }
        None => return Err(StoreError::corrupt("wal base path has no file name".into())),
    };
    let mut seqs = Vec::new();
    if !dir.exists() {
        return Ok(seqs);
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(suffix) = name.strip_prefix(&stem) {
            if let Ok(seq) = suffix.parse::<u64>() {
                seqs.push(seq);
            }
        }
    }
    seqs.sort_unstable();
    Ok(seqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_base(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bst_wal_{}_{}_{tag}", std::process::id(), line!()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("engine.wal")
    }

    fn cleanup(base: &Path) {
        if let Some(dir) = base.parent() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert { start_id: 0, n: 2, chars: vec![1, 2, 3, 4, 5, 6] },
            WalRecord::Delete { id: 1 },
            WalRecord::MergeMarker,
            WalRecord::Insert { start_id: 2, n: 1, chars: vec![7, 8, 9] },
        ]
    }

    #[test]
    fn append_reopen_roundtrip() {
        let base = tmp_base("roundtrip");
        let (mut wal, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert!(recs.is_empty());
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let (_, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records());
        assert_eq!(report.records, 4);
        assert_eq!(report.truncated_bytes, 0);
        cleanup(&base);
    }

    #[test]
    fn every_byte_prefix_replays_to_a_record_boundary() {
        let base = tmp_base("prefix");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Off).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let full = std::fs::read(segment_path(&base, 0)).unwrap();
        let all = sample_records();
        for cut in 0..=full.len() {
            let (recs, valid) = scan_segment(&full[..cut]);
            assert!(valid <= cut);
            assert_eq!(recs, all[..recs.len()], "prefix {cut}");
            // Valid prefix parses to exactly the records it contains.
            let (again, v2) = scan_segment(&full[..valid]);
            assert_eq!((again, v2), (recs, valid));
        }
        cleanup(&base);
    }

    #[test]
    fn torn_tail_truncated_on_open() {
        let base = tmp_base("torn");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let path = segment_path(&base, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let full = bytes.len();
        bytes.truncate(full - 2); // tear the last record
        bytes.extend_from_slice(&[0xAA; 1]); // plus garbage
        std::fs::write(&path, &bytes).unwrap();
        let (mut wal, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records()[..3]);
        assert!(report.truncated_bytes > 0);
        // Appends resume cleanly on the truncated boundary.
        wal.append(&WalRecord::Delete { id: 9 }).unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        let mut want = sample_records()[..3].to_vec();
        want.push(WalRecord::Delete { id: 9 });
        assert_eq!(recs, want);
        cleanup(&base);
    }

    #[test]
    fn corrupt_byte_truncates_from_that_record() {
        let base = tmp_base("corrupt");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let path = segment_path(&base, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a byte inside the second record's payload.
        let first = scan_segment(&bytes[..]).0[0].frame().len();
        bytes[first + FRAME_HEADER + 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records()[..1]);
        cleanup(&base);
    }

    #[test]
    fn rotation_isolates_and_commit_deletes() {
        let base = tmp_base("rotate");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        // Pre-commit: both segments' records replay, in order.
        let recs = read_records(&base).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }, WalRecord::Delete { id: 2 }]);
        wal.rotate_commit().unwrap();
        let recs = read_records(&base).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 2 }]);
        assert!(!segment_path(&base, 0).exists());
        assert!(segment_path(&base, 1).exists());
        drop(wal);
        // Reopen picks up the surviving segment and appends to it.
        let (mut wal, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs.len(), 1);
        wal.append(&WalRecord::Delete { id: 3 }).unwrap();
        cleanup(&base);
    }

    #[test]
    fn short_write_poisons_and_replay_drops_record() {
        let base = tmp_base("short");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        let scope = base.to_string_lossy().into_owned();
        failpoint::arm_scoped("wal.append.short", &scope, 0, 1, failpoint::Action::ShortWrite(5));
        let err = wal.append(&WalRecord::Delete { id: 2 });
        failpoint::clear("wal.append.short");
        assert!(err.is_err());
        // Poisoned: further appends refuse.
        assert!(wal.append(&WalRecord::Delete { id: 3 }).is_err());
        drop(wal);
        // The torn bytes vanish on reopen; only the acked record remains.
        let (_, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }]);
        assert_eq!(report.truncated_bytes, 5);
        cleanup(&base);
    }

    #[test]
    fn sync_failure_erases_partial_record() {
        let base = tmp_base("syncfail");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        let scope = base.to_string_lossy().into_owned();
        failpoint::arm_scoped("wal.sync", &scope, 0, 1, failpoint::Action::Error);
        let err = wal.append(&WalRecord::Delete { id: 2 });
        failpoint::clear("wal.sync");
        assert!(err.is_err());
        // The failed record's bytes were erased: the log stays usable
        // and a later append (possibly reusing the id) replays alone.
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }, WalRecord::Delete { id: 2 }]);
        cleanup(&base);
    }

    #[test]
    fn batch_sync_flushes_on_demand() {
        let base = tmp_base("batch");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Batch).unwrap();
        for i in 0..10 {
            wal.append(&WalRecord::Delete { id: i }).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Batch).unwrap();
        assert_eq!(recs.len(), 10);
        cleanup(&base);
    }

    #[test]
    fn wal_sync_parse() {
        assert_eq!(WalSync::parse("always"), Some(WalSync::Always));
        assert_eq!(WalSync::parse("batch"), Some(WalSync::Batch));
        assert_eq!(WalSync::parse("off"), Some(WalSync::Off));
        assert_eq!(WalSync::parse("sometimes"), None);
        assert_eq!(WalSync::Batch.as_str(), "batch");
    }

    fn chunk(f: WalFetch) -> WalChunk {
        match f {
            WalFetch::Chunk(c) => c,
            WalFetch::Gap => panic!("unexpected gap"),
        }
    }

    #[test]
    fn fetch_frames_tails_across_segments_to_the_frontier() {
        let base = tmp_base("fetch");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 3 }).unwrap();
        let c = chunk(fetch_frames(&base, WalCursor::default(), 1 << 20).unwrap());
        assert_eq!(c.records, 3);
        let (recs, used) = scan_frames(&c.frames);
        assert_eq!(used, c.frames.len(), "fetched bytes are whole frames");
        assert_eq!(
            recs,
            vec![
                WalRecord::Delete { id: 1 },
                WalRecord::Delete { id: 2 },
                WalRecord::Delete { id: 3 }
            ]
        );
        assert_eq!(c.next, wal.cursor(), "drained to the write frontier");
        // Re-fetching from the frontier: caught up, cursor unchanged.
        let c2 = chunk(fetch_frames(&base, c.next, 1 << 20).unwrap());
        assert!(c2.frames.is_empty());
        assert_eq!(c2.next, c.next);
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_respects_budget_and_chains_cursors() {
        let base = tmp_base("budget");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        // A 1-byte budget still makes progress (one frame per fetch);
        // chaining cursors reproduces the whole log in order.
        let mut cur = WalCursor::default();
        let mut got = Vec::new();
        for _ in 0..sample_records().len() {
            let c = chunk(fetch_frames(&base, cur, 1).unwrap());
            assert_eq!(c.records, 1, "take_one forces exactly one frame");
            got.extend(scan_frames(&c.frames).0);
            cur = c.next;
        }
        assert_eq!(got, sample_records());
        assert!(chunk(fetch_frames(&base, cur, 1).unwrap()).frames.is_empty());
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_gaps_on_rotated_or_misaligned_cursors() {
        let base = tmp_base("gap");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        wal.rotate_commit().unwrap(); // segment 0 is gone
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 0, off: 0 }, 1 << 20).unwrap(),
            WalFetch::Gap
        ));
        // Offset inside a frame: checksum can't line up → gap.
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 1, off: 1 }, 1 << 20).unwrap(),
            WalFetch::Gap
        ));
        // Offset past the valid tail → gap.
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 1, off: 1 << 40 }, 1 << 20).unwrap(),
            WalFetch::Gap
        ));
        // The surviving segment reads fine from its start.
        let c = chunk(fetch_frames(&base, WalCursor { seq: 1, off: 0 }, 1 << 20).unwrap());
        assert_eq!(scan_frames(&c.frames).0, vec![WalRecord::Delete { id: 2 }]);
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_on_missing_log_only_accepts_origin() {
        let dir = std::env::temp_dir()
            .join(format!("bst_wal_{}_{}_missing", std::process::id(), line!()));
        let base = dir.join("never-created.wal");
        let c = chunk(fetch_frames(&base, WalCursor::default(), 1024).unwrap());
        assert!(c.frames.is_empty());
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 3, off: 0 }, 1024).unwrap(),
            WalFetch::Gap
        ));
    }
}
