//! Per-engine write-ahead log: the durability layer behind every
//! acknowledged insert/delete.
//!
//! Snapshots persist the engine wholesale but only on explicit `save`;
//! everything acknowledged since lives in in-memory delta segments and
//! tombstone sets. The WAL closes that window: each write appends one
//! length-prefixed, FNV-1a-checksummed record (the same checksum
//! convention as the snapshot container) *before* the engine
//! acknowledges it, and `Engine::load` replays records past the
//! snapshot's id high-water mark on the next start.
//!
//! ## Record frame
//!
//! ```text
//! [u32 payload len LE] [u64 FNV-1a(payload) LE] [payload]
//! ```
//!
//! Payloads use the compact legacy byte layout (`ByteWriter::legacy`):
//! a `u8` kind tag, then per-kind fields — inserts carry the first
//! global id, the row count, and the flattened row characters; deletes
//! carry one id; merge markers carry nothing (they only record that the
//! in-memory segments were reorganized; replay ignores them).
//!
//! ## Torn tails
//!
//! A crash can leave a partial record at the very end of the newest
//! segment. Opening the log truncates at the first frame that is
//! incomplete, has an impossible length, fails its checksum, or fails
//! to parse — that prefix property (every byte-prefix of a WAL replays
//! cleanly up to a record boundary) is what the `prop_wal` suite
//! enforces. Records never straddle that point because an append that
//! errors mid-write erases its partial bytes (or, if even the erase
//! fails, permanently poisons the log so nothing further is
//! acknowledged).
//!
//! ## Segments and rotation
//!
//! The log is a sequence of files `{base}.{seq}`. `Engine::save`
//! rotates under the insert lock: a fresh segment opens *before* the
//! snapshot is written (`rotate_begin`) and the old segments are
//! deleted only *after* the snapshot has durably renamed into place
//! (`rotate_commit`). A crash between the two leaves extra old
//! segments whose records are all below the new snapshot's high-water
//! mark — replay skips them idempotently.
//!
//! ## Sync policies
//!
//! * [`WalSync::Always`] — fsync every record before acknowledging:
//!   an acknowledged write survives kill -9 and power loss.
//! * [`WalSync::Batch`] — write-through, fsync every
//!   [`BATCH_SYNC_BYTES`]: an OS crash can lose the unsynced suffix of
//!   acknowledged writes; a process kill cannot (the kernel holds the
//!   written bytes).
//! * [`WalSync::Off`] — never fsync: same process-kill guarantee as
//!   `Batch`, no protection against OS/power failure.
//!
//! ## Group commit
//!
//! Under `always`, fsyncing inside the insert lock serializes N
//! concurrent writers behind N fsyncs. [`Wal::enable_group`] moves the
//! fsync out of the lock: an append assigns a monotone LSN and buffers
//! its frame (page cache only), and the writer then blocks on
//! [`GroupCommit::wait_durable`] — the first writer to arrive while no
//! fsync is in flight becomes the group leader, fsyncs once for every
//! record appended so far, and publishes a durable-LSN watermark that
//! releases every writer at or below it. K writes landing in one
//! window cost one fsync instead of K, and `always` still means
//! "acknowledged ⇒ survives kill -9": nothing is acknowledged before
//! the watermark covers it. A failed group fsync fails every write in
//! the group — no false acks — while the buffered span is re-staged
//! for the next group's fsync ([`Wal::group_abort`]): the records' ids
//! are already woven into the engine's id sequence, so keeping them is
//! what keeps the log replayable (a retried write that later reaches
//! disk is at worst a false NACK). [`Wal::rotate_begin`] drains the
//! in-flight group before switching segments, so a snapshot's rotation
//! fence sees a fully durable log.

use super::container::checksum;
use super::sync_parent_dir as sync_dir;
use super::{ByteReader, ByteWriter, StoreError};
use crate::util::failpoint;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

/// Fsync cadence under [`WalSync::Batch`]: bytes written since the last
/// sync before the next append forces one.
pub const BATCH_SYNC_BYTES: u64 = 256 * 1024;

/// Frame header size: u32 payload length + u64 payload checksum.
const FRAME_HEADER: usize = 12;

/// Upper bound on a single record payload (a frame declaring more is
/// treated as torn, not allocated).
const MAX_RECORD_BYTES: u32 = 1 << 30;

/// Durability policy for WAL appends (`--wal-sync`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalSync {
    /// Fsync before every acknowledgement.
    Always,
    /// Fsync every [`BATCH_SYNC_BYTES`] of appended records.
    Batch,
    /// Never fsync (page cache only).
    Off,
}

impl WalSync {
    /// Parses the CLI spelling (`always` / `batch` / `off`).
    pub fn parse(s: &str) -> Option<WalSync> {
        match s {
            "always" => Some(WalSync::Always),
            "batch" => Some(WalSync::Batch),
            "off" => Some(WalSync::Off),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WalSync::Always => "always",
            WalSync::Batch => "batch",
            WalSync::Off => "off",
        }
    }
}

/// One logical WAL record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// `n` rows inserted with contiguous global ids starting at
    /// `start_id`; `chars` is the row characters flattened in id order
    /// (`n * L` bytes — `L` is implied by the engine replaying it).
    Insert { start_id: u32, n: u32, chars: Vec<u8> },
    /// One tombstoned global id.
    Delete { id: u32 },
    /// A background/forced merge folded delta rows into the base.
    /// Replay ignores it (merges don't change answers); it exists so an
    /// operator reading the log can correlate it with serving history.
    MergeMarker,
}

const KIND_INSERT: u8 = 1;
const KIND_DELETE: u8 = 2;
const KIND_MERGE: u8 = 3;

impl WalRecord {
    fn payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::legacy();
        match self {
            WalRecord::Insert { start_id, n, chars } => {
                w.put_u8(KIND_INSERT);
                w.put_u32(*start_id);
                w.put_u32(*n);
                w.put_bytes(chars);
            }
            WalRecord::Delete { id } => {
                w.put_u8(KIND_DELETE);
                w.put_u32(*id);
            }
            WalRecord::MergeMarker => w.put_u8(KIND_MERGE),
        }
        w.into_bytes()
    }

    fn parse(payload: &[u8]) -> Result<WalRecord, StoreError> {
        let mut r = ByteReader::legacy(payload);
        let rec = match r.get_u8()? {
            KIND_INSERT => {
                let start_id = r.get_u32()?;
                let n = r.get_u32()?;
                let chars = r.get_bytes()?.to_vec();
                if n as usize != 0 && chars.len() % n as usize != 0 {
                    return Err(StoreError::corrupt(format!(
                        "wal insert record: {} chars not divisible by {n} rows",
                        chars.len()
                    )));
                }
                WalRecord::Insert { start_id, n, chars }
            }
            KIND_DELETE => WalRecord::Delete { id: r.get_u32()? },
            KIND_MERGE => WalRecord::MergeMarker,
            k => {
                return Err(StoreError::corrupt(format!("wal record: unknown kind {k}")));
            }
        };
        r.expect_end()?;
        Ok(rec)
    }

    /// The full on-disk frame: header + payload.
    fn frame(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&checksum(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// What [`Wal::open`] found and did.
#[derive(Debug, Default)]
pub struct WalOpenReport {
    /// Segment files scanned.
    pub segments: usize,
    /// Valid records recovered (all segments, in order).
    pub records: usize,
    /// Torn/corrupt bytes truncated off the newest segment.
    pub truncated_bytes: u64,
}

/// An open, appendable write-ahead log.
pub struct Wal {
    base: PathBuf,
    /// `base` as a display string — the failpoint context, so tests
    /// scope injected faults to their own log.
    ctx: String,
    /// Shared so the group-commit leader can fsync outside the insert
    /// lock; writes go through `&File` (the file is opened `O_APPEND`,
    /// so every write lands atomically at the end regardless of which
    /// handle clone issued it).
    file: Arc<File>,
    /// Sequence number of the segment receiving appends.
    seq: u64,
    /// Valid length of the current segment.
    len: u64,
    sync: WalSync,
    /// Bytes appended since the last fsync ([`WalSync::Batch`]).
    pending: u64,
    /// Set when a failed append could not erase its partial bytes: the
    /// tail is untrustworthy, so every further append is refused.
    broken: bool,
    /// LSN the next append takes. Monotone from 1 and never reused —
    /// a failed LSN must never compare equal to a later durable one.
    next_lsn: u64,
    /// Group-commit state once [`Wal::enable_group`] ran: appends then
    /// buffer and the fsync moves to [`GroupCommit::wait_durable`].
    group: Option<Arc<GroupCommit>>,
}

impl Wal {
    /// Opens (or creates) the log at `base`, recovering every valid
    /// record from all segments in sequence order and truncating the
    /// torn tail of the newest segment. Appends resume at the
    /// truncation point.
    pub fn open(
        base: &Path,
        sync: WalSync,
    ) -> Result<(Wal, Vec<WalRecord>, WalOpenReport), StoreError> {
        let seqs = list_segments(base)?;
        let mut records = Vec::new();
        let mut report = WalOpenReport { segments: seqs.len().max(1), ..Default::default() };
        let mut last_valid = 0u64;
        for (i, &seq) in seqs.iter().enumerate() {
            let path = segment_path(base, seq);
            let bytes = std::fs::read(&path)?;
            let (recs, valid) = scan_segment(&bytes);
            records.extend(recs);
            if i + 1 == seqs.len() {
                // Newest segment: physically truncate the torn tail so
                // appends land on a record boundary.
                if (valid as u64) < bytes.len() as u64 {
                    report.truncated_bytes = bytes.len() as u64 - valid as u64;
                    let f = OpenOptions::new().write(true).open(&path)?;
                    f.set_len(valid as u64)?;
                    f.sync_data()?;
                }
                last_valid = valid as u64;
            }
        }
        report.records = records.len();
        let seq = seqs.last().copied().unwrap_or(0);
        let path = segment_path(base, seq);
        let created = !path.exists();
        let file = OpenOptions::new().append(true).create(true).open(&path)?;
        if created {
            sync_dir(base)?;
        }
        let wal = Wal {
            base: base.to_path_buf(),
            ctx: base.to_string_lossy().into_owned(),
            file: Arc::new(file),
            seq,
            len: last_valid,
            sync,
            pending: 0,
            broken: false,
            next_lsn: 1,
            group: None,
        };
        Ok((wal, records, report))
    }

    /// The segment-base path this log writes under.
    pub fn base(&self) -> &Path {
        &self.base
    }

    /// Appends one record and returns its LSN. Without group commit
    /// the record is durable per the sync policy on return; with it
    /// ([`Wal::enable_group`]) the frame is buffered and the caller
    /// must block on [`GroupCommit::wait_durable`] before
    /// acknowledging. On `Err` the record is guaranteed *not* to be
    /// replayed later: partial bytes are erased, or the log is
    /// poisoned so no later record can land after a torn one.
    pub fn append(&mut self, rec: &WalRecord) -> Result<u64, StoreError> {
        if self.broken {
            return Err(StoreError::corrupt(
                "wal is poisoned after a failed append; restart to recover".into(),
            ));
        }
        let frame = rec.frame();

        // Failpoint: simulate power loss mid-append — some prefix of
        // the frame reaches disk and the process is assumed dead, so no
        // cleanup runs. The log is poisoned to stop this process from
        // writing anything after the torn bytes.
        if let Some(failpoint::Action::ShortWrite(k)) =
            failpoint::check("wal.append.short", &self.ctx)
        {
            let k = k.min(frame.len());
            let _ = (&*self.file).write_all(&frame[..k]);
            let _ = self.file.sync_data();
            self.broken = true;
            return Err(StoreError::Io(failpoint::io_error("wal.append.short")));
        }

        let res = match &self.group {
            // Group mode: write through to the page cache only — the
            // group leader's single fsync covers this record.
            Some(_) => (&*self.file).write_all(&frame).map_err(StoreError::from),
            None => self.write_durable(&frame),
        };
        match res {
            Ok(()) => {
                self.len += frame.len() as u64;
                let lsn = self.next_lsn;
                self.next_lsn += 1;
                if let Some(group) = &self.group {
                    let mut g = group.m.lock().unwrap();
                    g.tail_lsn = lsn;
                    g.tail_len = self.len;
                }
                Ok(lsn)
            }
            Err(e) => {
                // Erase whatever partially landed so the *next* append
                // (which may reuse the rolled-back ids) can never sit
                // after a torn record that replay would misread.
                if self.file.set_len(self.len).is_err() {
                    self.broken = true;
                }
                self.pending = 0;
                Err(e)
            }
        }
    }

    fn write_durable(&mut self, frame: &[u8]) -> Result<(), StoreError> {
        (&*self.file).write_all(frame)?;
        if failpoint::check("wal.sync", &self.ctx) == Some(failpoint::Action::Error) {
            return Err(StoreError::Io(failpoint::io_error("wal.sync")));
        }
        match self.sync {
            WalSync::Always => self.file.sync_data()?,
            WalSync::Batch => {
                self.pending += frame.len() as u64;
                if self.pending >= BATCH_SYNC_BYTES {
                    self.file.sync_data()?;
                    self.pending = 0;
                }
            }
            WalSync::Off => {}
        }
        Ok(())
    }

    /// Switches appends to group commit: frames buffer in the page
    /// cache and durability moves to the returned [`GroupCommit`]'s
    /// watermark protocol. `rows` seeds the durable row count (the
    /// engine's size after replay); `window_us` is the extra wait the
    /// group leader spends letting more writers join before its fsync
    /// (0 = fsync immediately). Only meaningful under
    /// [`WalSync::Always`] — the other policies already defer.
    pub fn enable_group(&mut self, rows: u64, window_us: u64) -> Arc<GroupCommit> {
        let group = Arc::new(GroupCommit {
            m: Mutex::new(GroupInner {
                file: Some(Arc::clone(&self.file)),
                seq: self.seq,
                tail_lsn: 0,
                tail_len: self.len,
                tail_n: rows,
                durable_lsn: 0,
                durable_len: self.len,
                durable_n: rows,
                syncing: false,
                failed_hi: 0,
            }),
            cv: Condvar::new(),
            ctx: self.ctx.clone(),
            window_us,
        });
        self.group = Some(Arc::clone(&group));
        group
    }

    /// The group-commit handle, when [`Wal::enable_group`] ran.
    pub fn group(&self) -> Option<&Arc<GroupCommit>> {
        self.group.as_ref()
    }

    /// The configured sync policy.
    pub fn sync_mode(&self) -> WalSync {
        self.sync
    }

    /// Handles a failed group fsync. The buffered bytes past the
    /// durable frontier are *kept*, not discarded: the records' ids are
    /// already woven into the engine's id sequence, and erasing them
    /// would leave a gap that makes every later record unreplayable. A
    /// failed fsync leaves their page-cache state undefined (Linux can
    /// mark the pages clean without them reaching disk), so the span is
    /// read back, truncated off, and rewritten — freshly dirtied pages
    /// the *next* group's fsync retries. The failed LSNs are marked so
    /// their waiters error now instead of hanging; if a retry later
    /// succeeds those records become durable after all, which is at
    /// worst a false NACK — never a false ack. If the bytes cannot be
    /// read back or rewritten, the tail is erased to the durable
    /// frontier and the log refuses further appends (`broken`) — a
    /// clean durable prefix beats an appendable log with an id gap.
    /// Must run under the insert lock — it rewrites the append tail.
    /// If a rotation already drained the group this is a
    /// wake-up-only no-op.
    pub fn group_abort(&mut self) {
        let Some(group) = self.group.clone() else { return };
        let mut g = group.m.lock().unwrap();
        if g.tail_lsn > g.durable_lsn {
            g.failed_hi = g.tail_lsn;
            if !self.requeue_tail(g.durable_len, g.tail_len) {
                let _ = self.file.set_len(g.durable_len);
                self.broken = true;
                self.len = g.durable_len;
                g.tail_len = g.durable_len;
                g.tail_n = g.durable_n;
            }
        }
        g.syncing = false;
        group.cv.notify_all();
    }

    /// Re-stages `[from, to)` of the current segment for the next
    /// fsync: reads the span back, truncates it off, and appends the
    /// identical bytes (`O_APPEND` — they land exactly at `from`), so
    /// the kernel sees freshly dirtied pages rather than pages a
    /// failed fsync may have marked clean. Returns `false` when any
    /// step fails and the tail must be erased instead.
    fn requeue_tail(&mut self, from: u64, to: u64) -> bool {
        use std::io::{Read, Seek, SeekFrom};
        let Ok(len) = usize::try_from(to.saturating_sub(from)) else {
            return false;
        };
        let mut buf = vec![0u8; len];
        let mut f = &*self.file;
        if f.seek(SeekFrom::Start(from)).is_err() || f.read_exact(&mut buf).is_err() {
            return false;
        }
        if self.file.set_len(from).is_err() {
            return false;
        }
        f.write_all(&buf).is_ok()
    }

    /// Forces any deferred fsync ([`WalSync::Batch`]) to disk now.
    pub fn sync(&mut self) -> Result<(), StoreError> {
        if self.pending > 0 {
            self.file.sync_data()?;
            self.pending = 0;
        }
        Ok(())
    }

    /// Opens the next segment; subsequent appends go there. Called
    /// under the insert lock *before* a snapshot is written, so every
    /// record covering post-snapshot writes lives in the new segment.
    /// Old segments stay on disk until [`Wal::rotate_commit`]. With
    /// group commit this is the rotation fence: the in-flight group is
    /// drained (one unconditional fsync) and published durable before
    /// the segment switch, so the snapshot never covers un-synced
    /// records and the new segment starts with nothing pending.
    pub fn rotate_begin(&mut self) -> Result<(), StoreError> {
        match &self.group {
            Some(_) => self.file.sync_data()?,
            None => self.sync()?,
        }
        let seq = self.seq + 1;
        let file = Arc::new(
            OpenOptions::new()
                .append(true)
                .create(true)
                .open(segment_path(&self.base, seq))?,
        );
        sync_dir(&self.base)?;
        self.file = Arc::clone(&file);
        self.seq = seq;
        self.len = 0;
        self.pending = 0;
        self.broken = false;
        if let Some(group) = &self.group {
            let mut g = group.m.lock().unwrap();
            g.durable_lsn = g.tail_lsn;
            g.durable_n = g.tail_n;
            g.file = Some(file);
            g.seq = seq;
            g.tail_len = 0;
            g.durable_len = 0;
            group.cv.notify_all();
        }
        Ok(())
    }

    /// Deletes every segment older than the current one. Called only
    /// after the snapshot covering them has durably renamed into
    /// place; a crash before this leaves old segments whose records
    /// replay idempotently (all below the snapshot's high-water mark).
    pub fn rotate_commit(&mut self) -> Result<(), StoreError> {
        let mut removed = false;
        for seq in list_segments(&self.base)? {
            if seq < self.seq {
                std::fs::remove_file(segment_path(&self.base, seq))?;
                removed = true;
            }
        }
        if removed {
            sync_dir(&self.base)?;
        }
        Ok(())
    }

    /// The current append frontier: every record this log has accepted
    /// lives strictly before this cursor, and a [`fetch_frames`] from it
    /// returns only records appended afterwards. `Engine::save` hands
    /// this to replication bootstrap so a follower can tail from the
    /// exact position its snapshot covers.
    pub fn cursor(&self) -> WalCursor {
        WalCursor { seq: self.seq, off: self.len }
    }
}

/// What one [`GroupCommit::wait_durable`] call did on behalf of the
/// group: zeros for a pure waiter, the group totals for the leader.
/// The engine feeds these to the `wal_fsyncs` / `wal_group_records`
/// counters, making the coalescing ratio observable in `stats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupOutcome {
    /// Fsync syscalls this call issued (1 when it led a group).
    pub fsyncs: u64,
    /// Records that fsync made durable — the whole group's, not just
    /// the caller's own.
    pub records: u64,
}

/// Shared group-commit state: the durability watermark writers block
/// on, plus leader election. Appends advance the tail under the insert
/// lock; [`GroupCommit::wait_durable`] elects the first blocked writer
/// as leader, fsyncs once for everything appended so far, and wakes
/// the rest.
pub struct GroupCommit {
    m: Mutex<GroupInner>,
    cv: Condvar,
    /// Failpoint context (the wal base path) so tests scope injected
    /// `wal.sync` faults to their own log.
    ctx: String,
    /// Extra microseconds the leader waits before fsyncing, letting
    /// more writers join the group (0 = fsync immediately).
    window_us: u64,
}

struct GroupInner {
    /// Handle to the segment holding un-synced appends.
    file: Option<Arc<File>>,
    /// Segment seq of `file` — the publish epoch guard: a leader fsync
    /// that raced a rotation must not clobber the new segment's state.
    seq: u64,
    /// Highest LSN appended.
    tail_lsn: u64,
    /// Appended byte length of the current segment.
    tail_len: u64,
    /// Engine row count as of the latest appended insert.
    tail_n: u64,
    /// Highest LSN known durable — the watermark writers ack on.
    durable_lsn: u64,
    /// Durable byte length of the current segment: the frontier
    /// replication fetches are clamped to, because anything past it is
    /// page-cache-only and a group abort could still erase it.
    durable_len: u64,
    /// Engine row count as of the durable watermark.
    durable_n: u64,
    /// A leader is currently fsyncing outside the lock.
    syncing: bool,
    /// High end of the LSN range hit by failed group fsyncs: an
    /// `lsn <= failed_hi` that is not durable yet must error instead
    /// of waiting (its writer is told the write did not commit). The
    /// bytes stay staged for retry, so a later successful group can
    /// still carry such an LSN past the watermark — at that point it
    /// is simply durable (a false NACK already went out, never a
    /// false ack).
    failed_hi: u64,
}

impl GroupCommit {
    /// Blocks until `lsn` is durable (`Ok`) or its group's fsync
    /// failed (`Err`). The first caller to arrive while no fsync is in
    /// flight becomes the leader: it sleeps the group window, fsyncs
    /// once for every record appended so far, and publishes the
    /// watermark. On fsync failure the leader invokes `abort`, which
    /// must take the insert lock and call [`Wal::group_abort`] so the
    /// failed span is re-staged (or erased and the log poisoned)
    /// before any further append lands.
    pub fn wait_durable(
        &self,
        lsn: u64,
        abort: impl FnOnce(),
    ) -> Result<GroupOutcome, StoreError> {
        let mut outcome = GroupOutcome::default();
        let mut g = self.m.lock().unwrap();
        loop {
            if g.failed_hi >= lsn && g.durable_lsn < lsn {
                return Err(StoreError::Io(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "wal group fsync failed; write not acknowledged",
                )));
            }
            if g.durable_lsn >= lsn {
                return Ok(outcome);
            }
            if g.syncing {
                g = self.cv.wait(g).unwrap();
                continue;
            }
            // Leader: fsync everything appended so far, outside both
            // locks so new appends keep landing meanwhile.
            g.syncing = true;
            drop(g);
            if self.window_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(self.window_us));
            }
            let (file, up_to, up_len, up_n, epoch) = {
                let s = self.m.lock().unwrap();
                (s.file.clone(), s.tail_lsn, s.tail_len, s.tail_n, s.seq)
            };
            let synced =
                if failpoint::check("wal.sync", &self.ctx) == Some(failpoint::Action::Error) {
                    Err(StoreError::Io(failpoint::io_error("wal.sync")))
                } else {
                    match &file {
                        Some(f) => f.sync_data().map_err(StoreError::from),
                        None => Ok(()),
                    }
                };
            match synced {
                Ok(()) => {
                    g = self.m.lock().unwrap();
                    // Publish, unless a rotation switched segments
                    // mid-fsync — its drain already covered us.
                    if g.seq == epoch && g.durable_lsn < up_to {
                        outcome.fsyncs += 1;
                        outcome.records += up_to - g.durable_lsn;
                        g.durable_lsn = up_to;
                        g.durable_len = up_len;
                        g.durable_n = up_n;
                    }
                    g.syncing = false;
                    self.cv.notify_all();
                    // Loop: the watermark now covers our own lsn
                    // (directly, or via the rotation that drained it).
                }
                Err(e) => {
                    abort();
                    return Err(e);
                }
            }
        }
    }

    /// Records the engine row count the latest append brought the log
    /// to; published to [`GroupCommit::durable_rows`] when that
    /// append's group commits. Called under the insert lock.
    pub fn note_rows(&self, n: u64) {
        self.m.lock().unwrap().tail_n = n;
    }

    /// The durable frontier: replication fetches must not serve bytes
    /// at or past this cursor — they are page-cache-only and not yet
    /// acknowledged to any writer (a failed group fsync NACKs them,
    /// and the poison fallback of [`Wal::group_abort`] may erase them).
    pub fn durable_cursor(&self) -> WalCursor {
        let g = self.m.lock().unwrap();
        WalCursor { seq: g.seq, off: g.durable_len }
    }

    /// Engine row count at the durable watermark — what a primary
    /// reports as applied so follower lag is measured against fsynced
    /// state, not the buffered tail of an open group.
    pub fn durable_rows(&self) -> u64 {
        self.m.lock().unwrap().durable_n
    }
}

/// A position in the segmented log: segment sequence number plus byte
/// offset within that segment. Always sits on a frame boundary (the
/// fetch API only ever hands out frame-aligned cursors; a misaligned
/// cursor is detected by checksum and reported as a gap).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalCursor {
    pub seq: u64,
    pub off: u64,
}

/// One fetched span of raw frames, wire-ready: the bytes are exactly as
/// they sit on disk (length-prefixed, checksummed), so the receiver
/// re-verifies integrity with [`scan_frames`] before applying.
pub struct WalChunk {
    /// Concatenated raw frames (possibly spanning segment boundaries).
    pub frames: Vec<u8>,
    /// Number of whole records in `frames`.
    pub records: usize,
    /// Where the next fetch should resume.
    pub next: WalCursor,
}

/// Outcome of a cursor fetch.
pub enum WalFetch {
    /// Frames from the cursor forward (empty = caught up).
    Chunk(WalChunk),
    /// The cursor's segment no longer exists (rotated away) or the
    /// offset does not sit on a frame boundary: the tail from this
    /// position is unrecoverable and the reader must re-bootstrap from
    /// a snapshot.
    Gap,
}

/// Read-only cursor fetch: returns up to `max_bytes` of raw frames
/// starting at `from`, crossing segment boundaries, always whole frames
/// and always at least one when any is available (so a single oversized
/// record cannot wedge a small budget). Never writes; safe to run
/// concurrently with an appender — the scan stops at the last complete
/// frame, which only ever moves forward.
///
/// `limit` is the durable frontier under group commit: bytes at or
/// past it are complete frames in the page cache whose fsync has not
/// happened yet, so a group abort could still erase them — serving
/// them would let a follower apply a record the primary later rolls
/// back. `None` serves to the last complete frame (no group commit:
/// appends are durable, or the sync policy already tolerates loss).
pub fn fetch_frames(
    base: &Path,
    from: WalCursor,
    max_bytes: usize,
    limit: Option<WalCursor>,
) -> Result<WalFetch, StoreError> {
    let seqs = list_segments(base)?;
    if seqs.is_empty() {
        // No log yet: the origin cursor is trivially caught up;
        // anything else claims history that never existed here.
        return Ok(if from == WalCursor::default() {
            WalFetch::Chunk(WalChunk { frames: Vec::new(), records: 0, next: from })
        } else {
            WalFetch::Gap
        });
    }
    let Some(start) = seqs.iter().position(|&s| s == from.seq) else {
        return Ok(WalFetch::Gap);
    };
    let mut frames = Vec::new();
    let mut records = 0usize;
    let mut next = from;
    for (i, &seq) in seqs[start..].iter().enumerate() {
        if limit.is_some_and(|l| seq > l.seq) {
            break; // entirely past the durable frontier
        }
        let bytes = std::fs::read(segment_path(base, seq))?;
        let (_, mut valid) = scan_segment(&bytes);
        let clamped = limit.filter(|l| l.seq == seq);
        if let Some(l) = clamped {
            // Both bounds are frame boundaries, so the min is too.
            valid = valid.min(l.off as usize);
        }
        let off = if i == 0 { from.off as usize } else { 0 };
        if off > valid {
            return Ok(WalFetch::Gap);
        }
        let region = &bytes[off..valid];
        let (consumed, n) =
            take_frames(region, max_bytes.saturating_sub(frames.len()), frames.is_empty());
        if consumed == 0 && !region.is_empty() && frames.is_empty() {
            // A non-empty region whose first frame fails to parse:
            // the cursor is not on a frame boundary.
            return Ok(WalFetch::Gap);
        }
        frames.extend_from_slice(&region[..consumed]);
        records += n;
        next = WalCursor { seq, off: (off + consumed) as u64 };
        if consumed < region.len() {
            break; // budget exhausted mid-segment
        }
        if clamped.is_some() {
            break; // drained to the durable frontier — don't cross it
        }
        match seqs.get(start + i + 1) {
            // This segment is drained and a newer one exists: the next
            // fetch starts there.
            Some(&later) => next = WalCursor { seq: later, off: 0 },
            None => break, // at the write frontier
        }
    }
    Ok(WalFetch::Chunk(WalChunk { frames, records, next }))
}

/// Takes whole valid frames from the start of `bytes` up to `budget`
/// total bytes; `take_one` forces the first frame through regardless of
/// budget. Returns (bytes consumed, frames taken).
fn take_frames(bytes: &[u8], budget: usize, take_one: bool) -> (usize, usize) {
    let mut pos = 0usize;
    let mut n = 0usize;
    while bytes.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_RECORD_BYTES || (len as usize) > bytes.len() - pos - FRAME_HEADER {
            break;
        }
        let end = pos + FRAME_HEADER + len as usize;
        let payload = &bytes[pos + FRAME_HEADER..end];
        if checksum(payload) != sum || WalRecord::parse(payload).is_err() {
            break;
        }
        if end > budget && !(take_one && n == 0) {
            break;
        }
        pos = end;
        n += 1;
    }
    (pos, n)
}

/// Parses a span of raw frames (as produced by [`fetch_frames`]) back
/// into records, verifying every length and checksum. Returns the
/// records and the clean-prefix length — a receiver must treat anything
/// short of `bytes.len()` as transport corruption and re-fetch.
pub fn scan_frames(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    scan_segment(bytes)
}

/// Read-only scan of every valid record under `base` (all segments, in
/// order), tolerating a torn tail. Used by shard rebuild, which replays
/// while the engine's own `Wal` handle keeps appending — the scan never
/// truncates or otherwise writes.
pub fn read_records(base: &Path) -> Result<Vec<WalRecord>, StoreError> {
    let mut records = Vec::new();
    for seq in list_segments(base)? {
        let bytes = std::fs::read(segment_path(base, seq))?;
        let (recs, _) = scan_segment(&bytes);
        records.extend(recs);
    }
    Ok(records)
}

/// Parses frames from the start of `bytes`, stopping at the first torn
/// or corrupt frame. Returns the records and the clean-prefix length.
fn scan_segment(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_RECORD_BYTES || (len as usize) > bytes.len() - pos - FRAME_HEADER {
            break;
        }
        let payload = &bytes[pos + FRAME_HEADER..pos + FRAME_HEADER + len as usize];
        if checksum(payload) != sum {
            break;
        }
        match WalRecord::parse(payload) {
            Ok(rec) => records.push(rec),
            Err(_) => break,
        }
        pos += FRAME_HEADER + len as usize;
    }
    (records, pos)
}

/// The path of segment `seq`: `{base}.{seq}`.
fn segment_path(base: &Path, seq: u64) -> PathBuf {
    let mut name = base.file_name().map_or_else(String::new, |n| n.to_string_lossy().into_owned());
    name.push('.');
    name.push_str(&seq.to_string());
    base.with_file_name(name)
}

/// Existing segment sequence numbers under `base`, ascending.
fn list_segments(base: &Path) -> Result<Vec<u64>, StoreError> {
    let dir = base.parent().filter(|p| !p.as_os_str().is_empty()).unwrap_or(Path::new("."));
    let stem = match base.file_name() {
        Some(n) => {
            let mut s = n.to_string_lossy().into_owned();
            s.push('.');
            s
        }
        None => return Err(StoreError::corrupt("wal base path has no file name".into())),
    };
    let mut seqs = Vec::new();
    if !dir.exists() {
        return Ok(seqs);
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(suffix) = name.strip_prefix(&stem) {
            if let Ok(seq) = suffix.parse::<u64>() {
                seqs.push(seq);
            }
        }
    }
    seqs.sort_unstable();
    Ok(seqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_base(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bst_wal_{}_{}_{tag}", std::process::id(), line!()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("engine.wal")
    }

    fn cleanup(base: &Path) {
        if let Some(dir) = base.parent() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert { start_id: 0, n: 2, chars: vec![1, 2, 3, 4, 5, 6] },
            WalRecord::Delete { id: 1 },
            WalRecord::MergeMarker,
            WalRecord::Insert { start_id: 2, n: 1, chars: vec![7, 8, 9] },
        ]
    }

    #[test]
    fn append_reopen_roundtrip() {
        let base = tmp_base("roundtrip");
        let (mut wal, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert!(recs.is_empty());
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let (_, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records());
        assert_eq!(report.records, 4);
        assert_eq!(report.truncated_bytes, 0);
        cleanup(&base);
    }

    #[test]
    fn every_byte_prefix_replays_to_a_record_boundary() {
        let base = tmp_base("prefix");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Off).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let full = std::fs::read(segment_path(&base, 0)).unwrap();
        let all = sample_records();
        for cut in 0..=full.len() {
            let (recs, valid) = scan_segment(&full[..cut]);
            assert!(valid <= cut);
            assert_eq!(recs, all[..recs.len()], "prefix {cut}");
            // Valid prefix parses to exactly the records it contains.
            let (again, v2) = scan_segment(&full[..valid]);
            assert_eq!((again, v2), (recs, valid));
        }
        cleanup(&base);
    }

    #[test]
    fn torn_tail_truncated_on_open() {
        let base = tmp_base("torn");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let path = segment_path(&base, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let full = bytes.len();
        bytes.truncate(full - 2); // tear the last record
        bytes.extend_from_slice(&[0xAA; 1]); // plus garbage
        std::fs::write(&path, &bytes).unwrap();
        let (mut wal, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records()[..3]);
        assert!(report.truncated_bytes > 0);
        // Appends resume cleanly on the truncated boundary.
        wal.append(&WalRecord::Delete { id: 9 }).unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        let mut want = sample_records()[..3].to_vec();
        want.push(WalRecord::Delete { id: 9 });
        assert_eq!(recs, want);
        cleanup(&base);
    }

    #[test]
    fn corrupt_byte_truncates_from_that_record() {
        let base = tmp_base("corrupt");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let path = segment_path(&base, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a byte inside the second record's payload.
        let first = scan_segment(&bytes[..]).0[0].frame().len();
        bytes[first + FRAME_HEADER + 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records()[..1]);
        cleanup(&base);
    }

    #[test]
    fn rotation_isolates_and_commit_deletes() {
        let base = tmp_base("rotate");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        // Pre-commit: both segments' records replay, in order.
        let recs = read_records(&base).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }, WalRecord::Delete { id: 2 }]);
        wal.rotate_commit().unwrap();
        let recs = read_records(&base).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 2 }]);
        assert!(!segment_path(&base, 0).exists());
        assert!(segment_path(&base, 1).exists());
        drop(wal);
        // Reopen picks up the surviving segment and appends to it.
        let (mut wal, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs.len(), 1);
        wal.append(&WalRecord::Delete { id: 3 }).unwrap();
        cleanup(&base);
    }

    #[test]
    fn short_write_poisons_and_replay_drops_record() {
        let base = tmp_base("short");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        let scope = base.to_string_lossy().into_owned();
        failpoint::arm_scoped("wal.append.short", &scope, 0, 1, failpoint::Action::ShortWrite(5));
        let err = wal.append(&WalRecord::Delete { id: 2 });
        failpoint::clear("wal.append.short");
        assert!(err.is_err());
        // Poisoned: further appends refuse.
        assert!(wal.append(&WalRecord::Delete { id: 3 }).is_err());
        drop(wal);
        // The torn bytes vanish on reopen; only the acked record remains.
        let (_, recs, report) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }]);
        assert_eq!(report.truncated_bytes, 5);
        cleanup(&base);
    }

    #[test]
    fn sync_failure_erases_partial_record() {
        let base = tmp_base("syncfail");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        let scope = base.to_string_lossy().into_owned();
        failpoint::arm_scoped("wal.sync", &scope, 0, 1, failpoint::Action::Error);
        let err = wal.append(&WalRecord::Delete { id: 2 });
        failpoint::clear("wal.sync");
        assert!(err.is_err());
        // The failed record's bytes were erased: the log stays usable
        // and a later append (possibly reusing the id) replays alone.
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 1 }, WalRecord::Delete { id: 2 }]);
        cleanup(&base);
    }

    #[test]
    fn batch_sync_flushes_on_demand() {
        let base = tmp_base("batch");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Batch).unwrap();
        for i in 0..10 {
            wal.append(&WalRecord::Delete { id: i }).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Batch).unwrap();
        assert_eq!(recs.len(), 10);
        cleanup(&base);
    }

    #[test]
    fn wal_sync_parse() {
        assert_eq!(WalSync::parse("always"), Some(WalSync::Always));
        assert_eq!(WalSync::parse("batch"), Some(WalSync::Batch));
        assert_eq!(WalSync::parse("off"), Some(WalSync::Off));
        assert_eq!(WalSync::parse("sometimes"), None);
        assert_eq!(WalSync::Batch.as_str(), "batch");
    }

    fn chunk(f: WalFetch) -> WalChunk {
        match f {
            WalFetch::Chunk(c) => c,
            WalFetch::Gap => panic!("unexpected gap"),
        }
    }

    #[test]
    fn fetch_frames_tails_across_segments_to_the_frontier() {
        let base = tmp_base("fetch");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 3 }).unwrap();
        let c = chunk(fetch_frames(&base, WalCursor::default(), 1 << 20, None).unwrap());
        assert_eq!(c.records, 3);
        let (recs, used) = scan_frames(&c.frames);
        assert_eq!(used, c.frames.len(), "fetched bytes are whole frames");
        assert_eq!(
            recs,
            vec![
                WalRecord::Delete { id: 1 },
                WalRecord::Delete { id: 2 },
                WalRecord::Delete { id: 3 }
            ]
        );
        assert_eq!(c.next, wal.cursor(), "drained to the write frontier");
        // Re-fetching from the frontier: caught up, cursor unchanged.
        let c2 = chunk(fetch_frames(&base, c.next, 1 << 20, None).unwrap());
        assert!(c2.frames.is_empty());
        assert_eq!(c2.next, c.next);
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_respects_budget_and_chains_cursors() {
        let base = tmp_base("budget");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        for r in sample_records() {
            wal.append(&r).unwrap();
        }
        // A 1-byte budget still makes progress (one frame per fetch);
        // chaining cursors reproduces the whole log in order.
        let mut cur = WalCursor::default();
        let mut got = Vec::new();
        for _ in 0..sample_records().len() {
            let c = chunk(fetch_frames(&base, cur, 1, None).unwrap());
            assert_eq!(c.records, 1, "take_one forces exactly one frame");
            got.extend(scan_frames(&c.frames).0);
            cur = c.next;
        }
        assert_eq!(got, sample_records());
        assert!(chunk(fetch_frames(&base, cur, 1, None).unwrap()).frames.is_empty());
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_gaps_on_rotated_or_misaligned_cursors() {
        let base = tmp_base("gap");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.rotate_begin().unwrap();
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        wal.rotate_commit().unwrap(); // segment 0 is gone
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 0, off: 0 }, 1 << 20, None).unwrap(),
            WalFetch::Gap
        ));
        // Offset inside a frame: checksum can't line up → gap.
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 1, off: 1 }, 1 << 20, None).unwrap(),
            WalFetch::Gap
        ));
        // Offset past the valid tail → gap.
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 1, off: 1 << 40 }, 1 << 20, None).unwrap(),
            WalFetch::Gap
        ));
        // The surviving segment reads fine from its start.
        let c = chunk(fetch_frames(&base, WalCursor { seq: 1, off: 0 }, 1 << 20, None).unwrap());
        assert_eq!(scan_frames(&c.frames).0, vec![WalRecord::Delete { id: 2 }]);
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_on_missing_log_only_accepts_origin() {
        let dir = std::env::temp_dir()
            .join(format!("bst_wal_{}_{}_missing", std::process::id(), line!()));
        let base = dir.join("never-created.wal");
        let c = chunk(fetch_frames(&base, WalCursor::default(), 1024, None).unwrap());
        assert!(c.frames.is_empty());
        assert!(matches!(
            fetch_frames(&base, WalCursor { seq: 3, off: 0 }, 1024, None).unwrap(),
            WalFetch::Gap
        ));
    }

    #[test]
    fn group_commit_one_fsync_covers_every_buffered_record() {
        let base = tmp_base("group");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        let group = wal.enable_group(0, 0);
        let mut last = 0;
        for r in sample_records() {
            last = wal.append(&r).unwrap();
        }
        // One leader fsync publishes the whole group.
        let out = group.wait_durable(last, || panic!("no abort expected")).unwrap();
        assert_eq!((out.fsyncs, out.records), (1, 4));
        // Earlier LSNs are already under the watermark: no new fsync.
        let out = group.wait_durable(1, || panic!("no abort expected")).unwrap();
        assert_eq!((out.fsyncs, out.records), (0, 0));
        assert_eq!(group.durable_cursor(), wal.cursor());
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(recs, sample_records());
        cleanup(&base);
    }

    #[test]
    fn failed_group_fsync_nacks_the_group_and_retries_on_the_next() {
        let base = tmp_base("groupfail");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        let group = wal.enable_group(0, 0);
        let a = wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        group.wait_durable(a, || panic!("no abort expected")).unwrap();
        let b = wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        let c = wal.append(&WalRecord::Delete { id: 3 }).unwrap();
        let scope = base.to_string_lossy().into_owned();
        failpoint::arm_scoped("wal.sync", &scope, 0, 1, failpoint::Action::Error);
        let err = group.wait_durable(c, || wal.group_abort());
        failpoint::clear("wal.sync");
        assert!(err.is_err(), "leader propagates the fsync failure");
        // Every LSN in the failed group errors, including ones the
        // leader did not wait for.
        assert!(group.wait_durable(b, || panic!("no second abort")).is_err());
        // The failed frontier is what replication may serve: nothing
        // past the last successful fsync.
        assert_eq!(group.durable_cursor().off as usize, FRAME_HEADER + 5);
        // The log accepts new appends, and the next group's fsync
        // retries the failed span — the NACKed records become durable
        // after all (a false NACK, never a false ack).
        let d = wal.append(&WalRecord::Delete { id: 9 }).unwrap();
        assert!(d > c, "LSNs are never reused after a failure");
        let out = group.wait_durable(d, || panic!("no abort expected")).unwrap();
        assert_eq!((out.fsyncs, out.records), (1, 3), "retry covers b, c and d");
        assert_eq!(group.durable_cursor(), wal.cursor());
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert_eq!(
            recs,
            vec![
                WalRecord::Delete { id: 1 },
                WalRecord::Delete { id: 2 },
                WalRecord::Delete { id: 3 },
                WalRecord::Delete { id: 9 },
            ],
            "the re-staged span kept the record sequence gap-free"
        );
        cleanup(&base);
    }

    #[test]
    fn rotation_drains_the_open_group() {
        let base = tmp_base("groupdrain");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        let group = wal.enable_group(0, 0);
        let a = wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.rotate_begin().unwrap();
        // The fence fsynced the old segment: the record is durable
        // without any leader running.
        let out = group.wait_durable(a, || panic!("no abort expected")).unwrap();
        assert_eq!(out.fsyncs, 0);
        assert_eq!(group.durable_cursor(), WalCursor { seq: 1, off: 0 });
        wal.rotate_commit().unwrap();
        drop(wal);
        let (_, recs, _) = Wal::open(&base, WalSync::Always).unwrap();
        assert!(recs.is_empty(), "rotation committed past the drained record");
        cleanup(&base);
    }

    #[test]
    fn fetch_frames_clamps_to_the_durable_frontier() {
        let base = tmp_base("clamp");
        let (mut wal, _, _) = Wal::open(&base, WalSync::Always).unwrap();
        let group = wal.enable_group(0, 0);
        let a = wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        group.wait_durable(a, || panic!("no abort expected")).unwrap();
        let durable = group.durable_cursor();
        let _ = wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        // Unclamped, the buffered record is visible; clamped, the
        // fetch stops exactly at the watermark and reports caught-up.
        let all = chunk(fetch_frames(&base, WalCursor::default(), 1 << 20, None).unwrap());
        assert_eq!(all.records, 2);
        let c = chunk(fetch_frames(&base, WalCursor::default(), 1 << 20, Some(durable)).unwrap());
        assert_eq!(c.records, 1);
        assert_eq!(c.next, durable);
        let c2 = chunk(fetch_frames(&base, durable, 1 << 20, Some(durable)).unwrap());
        assert!(c2.frames.is_empty());
        assert_eq!(c2.next, durable);
        cleanup(&base);
    }
}
