//! Dependency-free read-only memory mapping.
//!
//! The zero-copy serving mode ([`crate::store::container::Snapshot::open_mapped`])
//! maps the snapshot file instead of reading it into an owned buffer, so
//! immutable section payloads can be served straight from the page cache.
//! Rust's standard library has no mmap wrapper and this repo takes no
//! external dependencies, so the needed libc entry points (`mmap`,
//! `munmap`, `madvise`, `mincore`) are declared here directly over
//! [`File::as_raw_fd`].
//!
//! Scope is deliberately tiny: whole-file, `PROT_READ`, `MAP_PRIVATE`
//! (read-only — a private mapping of an immutable snapshot never faults
//! a dirty page), unmapped on drop. Callers share the mapping through an
//! `Arc`; the last clone to die runs `munmap`. On non-unix targets
//! [`Mmap::map`] returns `Err`, and every caller falls back to the owned
//! (`std::fs::read`) load path.

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = !0usize as *mut c_void;
    // madvise advice values. These are identical on Linux and the BSDs
    // (including macOS), the only unix targets this crate maps on.
    pub const MADV_RANDOM: c_int = 1;
    pub const MADV_WILLNEED: c_int = 3;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
        // `vec` is `unsigned char*` on Linux and `char*` on the BSDs;
        // `*mut u8` is layout-compatible with both.
        pub fn mincore(addr: *mut c_void, len: usize, vec: *mut u8) -> c_int;
        pub fn getpagesize() -> c_int;
    }
}

/// A read-only mapping of an entire file.
#[derive(Debug)]
pub struct Mmap {
    /// Base address; dangling (never dereferenced, never unmapped) when
    /// `len == 0` — a zero-length `mmap` is `EINVAL`, so empty files are
    /// represented without a kernel mapping at all.
    ptr: *const u8,
    len: usize,
}

// The mapping is immutable (PROT_READ) and owned until drop, so shared
// references to its bytes are valid from any thread.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Maps `file` read-only in its entirety. Errors (platform without
    /// mmap, exotic file kinds, resource limits) are returned so the
    /// caller can fall back to an owned read.
    pub fn map(file: &std::fs::File) -> std::io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let len = file.metadata()?.len();
            let len = usize::try_from(len).map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "file too large to map on this platform",
                )
            })?;
            if len == 0 {
                return Ok(Mmap { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
            }
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Mmap { ptr: ptr as *const u8, len })
        }
        #[cfg(not(unix))]
        {
            let _ = file;
            Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap is unavailable on this platform",
            ))
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mapped bytes. Valid for the lifetime of the mapping (callers
    /// keep the `Arc<Mmap>` alive alongside any derived pointer).
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        // Safety: `ptr` is either a live PROT_READ mapping of exactly
        // `len` bytes or dangling with `len == 0`; both satisfy
        // `from_raw_parts`.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// How many of the mapping's bytes are resident in the page cache
    /// right now (`mincore`), rounded up to whole pages. `None` when the
    /// platform has no `mincore` or the probe fails — the stats endpoint
    /// reports that as `null` rather than a fake zero. Operators use
    /// this to see cold-page risk on a freshly mapped snapshot before
    /// traffic warms it.
    pub fn resident_bytes(&self) -> Option<usize> {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return Some(0);
            }
            let page = unsafe { sys::getpagesize() };
            let page = usize::try_from(page).ok().filter(|&p| p > 0)?;
            let pages = self.len.div_ceil(page);
            let mut vec = vec![0u8; pages];
            // Safety: `ptr` is a live page-aligned mapping of `len`
            // bytes (mmap returns page-aligned addresses) and `vec`
            // holds one byte per page of it.
            let rc = unsafe {
                sys::mincore(self.ptr as *mut std::os::raw::c_void, self.len, vec.as_mut_ptr())
            };
            if rc != 0 {
                return None;
            }
            let resident_pages = vec.iter().filter(|&&v| v & 1 != 0).count();
            Some((resident_pages * page).min(self.len))
        }
        #[cfg(not(unix))]
        {
            None
        }
    }

    /// Advises the kernel the whole mapping will be accessed randomly
    /// (`MADV_RANDOM`), disabling readahead — trie descent and plane-word
    /// probes touch scattered pages, and sequential readahead on a large
    /// snapshot only evicts hotter pages. Returns the number of bytes the
    /// advice covered, `None` when the platform has no `madvise` or the
    /// call fails (advice is best-effort; the mapping still works).
    pub fn advise_random(&self) -> Option<usize> {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return Some(0);
            }
            // Safety: `ptr` is a live page-aligned mapping of `len` bytes.
            let rc = unsafe {
                sys::madvise(self.ptr as *mut std::os::raw::c_void, self.len, sys::MADV_RANDOM)
            };
            if rc == 0 {
                Some(self.len)
            } else {
                None
            }
        }
        #[cfg(not(unix))]
        {
            None
        }
    }

    /// Advises the kernel to pre-fault `[offset, offset + len)` of the
    /// mapping (`MADV_WILLNEED`) — used to pre-touch the plane-word
    /// sections of a freshly mapped snapshot so the first queries do not
    /// eat a cold-page fault per probe. The range is widened down to a
    /// page boundary (the mapping base is page-aligned, so any in-range
    /// page start is too) and clamped to the mapping. Returns the number
    /// of bytes covered, `None` when unsupported or the call fails.
    pub fn advise_willneed(&self, offset: usize, len: usize) -> Option<usize> {
        #[cfg(unix)]
        {
            if offset >= self.len || len == 0 {
                return Some(0);
            }
            let page = unsafe { sys::getpagesize() };
            let page = usize::try_from(page).ok().filter(|&p| p > 0)?;
            let start = (offset / page) * page;
            let end = offset.saturating_add(len).min(self.len);
            let span = end - start;
            // Safety: `ptr + start` is page-aligned inside a live mapping
            // and `span` bytes stay within it.
            let rc = unsafe {
                sys::madvise(
                    self.ptr.add(start) as *mut std::os::raw::c_void,
                    span,
                    sys::MADV_WILLNEED,
                )
            };
            if rc == 0 {
                Some(span)
            } else {
                None
            }
        }
        #[cfg(not(unix))]
        {
            let _ = (offset, len);
            None
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self.len > 0 {
            // Safety: `ptr`/`len` came from a successful mmap and are
            // unmapped exactly once (Mmap is neither Clone nor Copy).
            unsafe {
                sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bst_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(contents).unwrap();
        path
    }

    #[test]
    #[cfg(unix)]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let path = tmp("contents.bin", &data);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.as_slice(), &data[..]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn empty_file_maps_to_empty_slice() {
        let path = tmp("empty.bin", &[]);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), &[] as &[u8]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn resident_bytes_probe() {
        let data = vec![3u8; 4096 * 4];
        let path = tmp("resident.bin", &data);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        // Touch every byte so the pages are resident, then probe.
        let sum: u64 = m.as_slice().iter().map(|&b| b as u64).sum();
        assert_eq!(sum, 3 * data.len() as u64);
        if let Some(r) = m.resident_bytes() {
            assert!(r <= m.len());
            assert!(r > 0, "just-touched mapping reports zero resident bytes");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn advice_covers_the_requested_ranges() {
        let data = vec![9u8; 4096 * 4 + 100];
        let path = tmp("advice.bin", &data);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        if let Some(n) = m.advise_random() {
            assert_eq!(n, m.len());
        }
        // Mid-mapping range is widened down to a page boundary and
        // clamped to the mapping's end.
        if let Some(n) = m.advise_willneed(4100, 4096) {
            assert!(n >= 4096, "willneed span too small: {n}");
            assert!(n <= m.len());
        }
        // Degenerate ranges are a zero-byte no-op, not an error.
        assert_eq!(m.advise_willneed(m.len(), 1), Some(0));
        assert_eq!(m.advise_willneed(0, 0), Some(0));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn mapping_survives_arc_sharing_across_threads() {
        let data = vec![7u8; 4096 * 3 + 5];
        let path = tmp("shared.bin", &data);
        let m = std::sync::Arc::new(Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || m.as_slice().iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * data.len() as u64);
        }
        std::fs::remove_file(&path).unwrap();
    }
}
