//! Multi-index framework (§III-B) with pluggable per-block filters.
//!
//! `MultiIndex<F>` partitions sketches into `m` blocks, builds one filter
//! per block, and answers a query in two steps:
//!
//! 1. **filter** — each block `j` with threshold `θ_j` (see
//!    [`super::blocks`]) reports candidate ids whose block is within
//!    `θ_j` of the query block;
//! 2. **verification** — candidates are deduplicated (epoch array — no
//!    clearing between queries) into a reusable buffer, sorted ascending
//!    (the kernel then streams monotone item ids — sequential plane-word
//!    loads), and each block's buffer is verified in **one batched
//!    kernel call**
//!    ([`crate::sketch::VerticalSet::ham_many_leq`]) against the
//!    collector's *live* threshold, so top-k queries tighten verification
//!    as the heap fills. (Verification of a block's candidates happens
//!    after that block's filtering rather than interleaved per candidate;
//!    result sets are unchanged — adaptive collectors only ever tighten.)
//!
//! All per-query state (epoch array, packed query planes, the bST block
//! filter's traversal scratch) lives behind one mutex and is reused
//! across queries — the multi-index analogue of the engine's per-worker
//! `QueryCtx` pooling.
//!
//! `MI-bST` instantiates `F` = per-block bST; [`super::mih`] and
//! [`super::hmsearch`] provide the hash-table backends.

use super::blocks::{block_ranges, block_thresholds};
use super::SearchIndex;
use crate::query::{CollectIds, Collector, QueryCtx};
use crate::sketch::{SketchSet, VerticalSet};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::trie::bst::{BstConfig, BstTrie};
use crate::trie::{SketchTrie, SortedSketches};
use crate::util::HeapSize;
use std::sync::Mutex;

/// Reusable scratch handed to block filters on every query (kept inside
/// the index's query-state mutex, so it is warmed once and reused).
pub struct BlockScratch {
    /// Traversal scratch for trie-backed filters.
    pub ctx: QueryCtx,
    /// Hit buffer for filters that materialize their candidates.
    pub hits: Vec<u32>,
    /// Row buffer for filters that enumerate signature rows in place.
    pub row: Vec<u8>,
}

/// Per-block candidate filter.
pub trait BlockFilter: Send + Sync {
    /// Builds over the block substrings of every sketch.
    fn build(block: &SketchSet) -> Self;

    /// Invokes `emit(id)` for every sketch whose block is within `tau_j`
    /// of `q_block` (duplicates allowed; the framework deduplicates).
    fn candidates(
        &self,
        q_block: &[u8],
        tau_j: usize,
        scratch: &mut BlockScratch,
        emit: &mut dyn FnMut(u32),
    );

    fn heap_bytes(&self) -> usize;

    fn filter_name() -> &'static str;

    /// Block substring length this filter was built over — snapshot
    /// validation cross-checks it against the block partition so a
    /// mismatched filter is rejected at load, not at query time.
    fn block_len(&self) -> usize;

    /// Largest sketch id this filter can emit (`None` when empty) —
    /// snapshot validation bounds it by the database size (emitted ids
    /// index the epoch array and the verification store).
    fn max_id(&self) -> Option<u32>;

    /// Alphabet bits `b` the filter was built over — snapshot validation
    /// cross-checks it against the verification store so a mismatched
    /// pairing cannot produce silently wrong Hamming verdicts.
    fn alphabet_bits(&self) -> usize;
}

/// Query-time candidate statistics (exposed for the eval harness).
#[derive(Debug, Clone, Copy, Default)]
pub struct FilterStats {
    /// Candidates emitted by all blocks (with duplicates).
    pub emitted: usize,
    /// Distinct candidates verified.
    pub verified: usize,
    /// Final solutions.
    pub solutions: usize,
}

/// Epoch-based visited set: `O(1)` clear between queries.
struct Visited {
    epoch: Vec<u32>,
    cur: u32,
}

impl Visited {
    fn new(n: usize) -> Self {
        Visited { epoch: vec![0; n], cur: 0 }
    }

    fn next_query(&mut self) {
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            self.epoch.fill(0);
            self.cur = 1;
        }
    }

    #[inline]
    fn insert(&mut self, id: u32) -> bool {
        let e = &mut self.epoch[id as usize];
        if *e == self.cur {
            false
        } else {
            *e = self.cur;
            true
        }
    }
}

/// All mutable per-query state, reused across queries.
struct QueryState {
    visited: Visited,
    scratch: BlockScratch,
    q_planes: Vec<u64>,
    /// Deduplicated candidates of one block, verified in a single
    /// batched kernel call.
    cands: Vec<u32>,
}

/// Generic multi-index.
pub struct MultiIndex<F: BlockFilter> {
    m: usize,
    ranges: Vec<(usize, usize)>,
    filters: Vec<F>,
    /// Full sketches in vertical format for verification.
    vertical: VerticalSet,
    state: Mutex<QueryState>,
}

impl<F: BlockFilter> MultiIndex<F> {
    /// Partitions into `m` blocks and builds the per-block filters.
    pub fn build(set: &SketchSet, m: usize) -> Self {
        assert!(m >= 1 && m <= set.l());
        let ranges = block_ranges(set.l(), m);
        let filters = ranges
            .iter()
            .map(|&(lo, hi)| F::build(&set.slice_block(lo, hi)))
            .collect();
        MultiIndex {
            m,
            ranges,
            filters,
            vertical: VerticalSet::from_horizontal(set),
            state: Mutex::new(QueryState {
                visited: Visited::new(set.n()),
                scratch: BlockScratch {
                    ctx: QueryCtx::new(),
                    hits: Vec::new(),
                    row: Vec::new(),
                },
                q_planes: Vec::new(),
                cands: Vec::new(),
            }),
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Database size (rows in the verification store).
    pub fn n(&self) -> usize {
        self.vertical.n()
    }

    /// Sketch length `L`.
    pub fn l(&self) -> usize {
        self.vertical.l()
    }

    /// Alphabet bits `b` (from the verification store).
    pub fn b(&self) -> usize {
        self.vertical.b()
    }

    /// Filter + verify, streaming solutions into the collector. `tau` is
    /// the threshold the block assignment plans for (the collector's tau
    /// at entry); verification prunes against the live `c.tau()`.
    fn run_filtered(&self, q: &[u8], tau: usize, c: &mut dyn Collector, stats: &mut FilterStats) {
        let mut guard = self.state.lock().unwrap();
        self.run_filtered_locked(&mut guard, q, tau, c, stats);
    }

    /// Lock-free core of [`Self::run_filtered`]: the caller holds the
    /// query-state guard. Blocked execution acquires the lock once per
    /// query block and drives every member query through this path, so
    /// per-query filtering/verification order — and therefore results and
    /// stats — are exactly the serial ones.
    fn run_filtered_locked(
        &self,
        state: &mut QueryState,
        q: &[u8],
        tau: usize,
        c: &mut dyn Collector,
        stats: &mut FilterStats,
    ) {
        assert_eq!(q.len(), self.vertical.l());
        let thresholds = block_thresholds(tau, self.m);
        let vertical = &self.vertical;

        let QueryState { visited, scratch, q_planes, cands } = state;
        visited.next_query();
        vertical.pack_query_into(q, q_planes);
        for (j, &(lo, hi)) in self.ranges.iter().enumerate() {
            let Some(tau_j) = thresholds[j] else { continue };
            let q_block = &q[lo..hi];
            // Filter: deduplicate this block's candidates into the
            // reusable buffer (no verification yet).
            cands.clear();
            {
                let visited = &mut *visited;
                let stats = &mut *stats;
                let cands = &mut *cands;
                self.filters[j].candidates(q_block, tau_j, scratch, &mut |id| {
                    stats.emitted += 1;
                    if visited.insert(id) {
                        stats.verified += 1;
                        cands.push(id);
                    }
                });
            }
            // Verify: one batched bit-parallel kernel call per block,
            // against the collector's live threshold. Candidates are
            // sorted first so the kernel streams monotone item ids —
            // sequential plane-word loads instead of random jumps.
            cands.sort_unstable();
            vertical.ham_many_leq(cands, q_planes, c.tau(), |id, verdict| {
                if let Some(d) = verdict {
                    c.emit(&[id], d);
                }
                Some(c.tau())
            });
        }
    }

    /// Search with per-query statistics.
    pub fn search_with_stats(&self, q: &[u8], tau: usize) -> (Vec<u32>, FilterStats) {
        let mut stats = FilterStats::default();
        let mut out = Vec::new();
        let mut coll = CollectIds::new(tau, &mut out);
        self.run_filtered(q, tau, &mut coll, &mut stats);
        stats.solutions = out.len();
        (out, stats)
    }
}

/// Persistence: block partition + per-block filters + the verification
/// store. The pooled query state (epoch array, scratch) is construction-
/// only and rebuilt fresh on load.
impl<F: BlockFilter + Persist> Persist for MultiIndex<F> {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.m);
        for &(lo, hi) in &self.ranges {
            w.put_usize(lo);
            w.put_usize(hi);
        }
        for f in &self.filters {
            f.write_into(w);
        }
        self.vertical.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let m = r.get_usize()?;
        ensure((1..=4096).contains(&m), || format!("multi-index: bad m {m}"))?;
        let mut ranges = Vec::with_capacity(m);
        for _ in 0..m {
            let lo = r.get_usize()?;
            let hi = r.get_usize()?;
            ranges.push((lo, hi));
        }
        let mut filters = Vec::with_capacity(m);
        for _ in 0..m {
            filters.push(F::read_from(r)?);
        }
        let vertical = VerticalSet::read_from(r)?;
        // Ranges must tile [0, L) in order.
        let mut expect = 0usize;
        for &(lo, hi) in &ranges {
            ensure(lo == expect && hi > lo, || {
                format!("multi-index: block range {lo}..{hi} does not tile")
            })?;
            expect = hi;
        }
        ensure(expect == vertical.l(), || {
            format!("multi-index: blocks cover {expect} of L={}", vertical.l())
        })?;
        let n = vertical.n();
        for (j, (&(lo, hi), f)) in ranges.iter().zip(&filters).enumerate() {
            ensure(f.block_len() == hi - lo, || {
                format!(
                    "multi-index: filter {j} is over {}-char blocks, range is {lo}..{hi}",
                    f.block_len()
                )
            })?;
            ensure(f.max_id().map_or(true, |m| (m as usize) < n), || {
                format!("multi-index: filter {j} emits ids beyond n={n}")
            })?;
            ensure(f.alphabet_bits() == vertical.b(), || {
                format!(
                    "multi-index: filter {j} alphabet b={} != verification store b={}",
                    f.alphabet_bits(),
                    vertical.b()
                )
            })?;
        }
        Ok(MultiIndex {
            m,
            ranges,
            filters,
            vertical,
            state: Mutex::new(QueryState {
                visited: Visited::new(n),
                scratch: BlockScratch {
                    ctx: QueryCtx::new(),
                    hits: Vec::new(),
                    row: Vec::new(),
                },
                q_planes: Vec::new(),
                cands: Vec::new(),
            }),
        })
    }
}

/// bST block filters persist as their trie.
impl Persist for BstBlockFilter {
    fn write_into(&self, w: &mut ByteWriter) {
        self.trie.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        Ok(BstBlockFilter { trie: BstTrie::read_from(r)? })
    }
}

impl<F: BlockFilter> SearchIndex for MultiIndex<F> {
    fn run(&self, q: &[u8], _ctx: &mut QueryCtx, c: &mut dyn Collector) {
        // Internal pooled scratch is used instead of the caller's ctx: the
        // epoch array must match this index's database size.
        let mut stats = FilterStats::default();
        self.run_filtered(q, c.tau(), c, &mut stats);
    }

    fn run_block(
        &self,
        qs: &[&[u8]],
        _ctx: &mut QueryCtx,
        bc: &mut crate::query::BlockCollector,
    ) {
        assert_eq!(qs.len(), bc.len(), "query block / collector slot mismatch");
        // Hoist the per-query setup the lock protects: one acquisition
        // serves the whole block, and each member query's dedup'd
        // candidate buffer is verified with the same batched kernel call
        // the serial path uses, in the same order.
        let mut guard = self.state.lock().unwrap();
        for (j, q) in qs.iter().enumerate() {
            let mut stats = FilterStats::default();
            let tau = bc.tau(j);
            let mut slot = crate::query::SlotRef::new(bc, j);
            self.run_filtered_locked(&mut guard, q, tau, &mut slot, &mut stats);
        }
    }

    fn heap_bytes(&self) -> usize {
        self.filters.iter().map(|f| f.heap_bytes()).sum::<usize>()
            + self.vertical.heap_bytes()
            + self.state.lock().unwrap().visited.epoch.heap_bytes()
    }

    fn name(&self) -> String {
        format!("{} (m={})", F::filter_name(), self.m)
    }
}

/// bST as a per-block filter: the block trie's leaves hold the ids of all
/// sketches sharing the block value — exactly an inverted index, searched
/// by traversal instead of signature probing. The traversal reuses the
/// shared [`BlockScratch`], so filtering allocates nothing after warm-up.
pub struct BstBlockFilter {
    trie: BstTrie,
}

impl BlockFilter for BstBlockFilter {
    fn build(block: &SketchSet) -> Self {
        let ss = SortedSketches::build(block);
        BstBlockFilter { trie: BstTrie::build(&ss, BstConfig::default()) }
    }

    fn candidates(
        &self,
        q_block: &[u8],
        tau_j: usize,
        scratch: &mut BlockScratch,
        emit: &mut dyn FnMut(u32),
    ) {
        let BlockScratch { ctx, hits, .. } = scratch;
        hits.clear();
        let mut coll = CollectIds::new(tau_j, hits);
        self.trie.run(q_block, ctx, &mut coll);
        for &id in hits.iter() {
            emit(id);
        }
    }

    fn heap_bytes(&self) -> usize {
        SketchTrie::heap_bytes(&self.trie)
    }

    fn filter_name() -> &'static str {
        "MI-bST"
    }

    fn block_len(&self) -> usize {
        self.trie.sketch_len()
    }

    fn max_id(&self) -> Option<u32> {
        self.trie.max_posting()
    }

    fn alphabet_bits(&self) -> usize {
        self.trie.alphabet_bits()
    }
}

/// `MI-bST`: multi-index with bST block filters.
pub type MultiBst = MultiIndex<BstBlockFilter>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn clustered_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..15)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut row = centers[rng.below_usize(15)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(l);
                    row[p] = rng.below(1 << b) as u8;
                }
                row
            })
            .collect()
    }

    #[test]
    fn matches_linear_scan_all_m() {
        let rows = clustered_rows(2, 16, 900, 51);
        let set = SketchSet::from_rows(2, 16, &rows);
        let mut rng = Rng::new(52);
        for m in [2usize, 3, 4] {
            let mi = MultiBst::build(&set, m);
            for _ in 0..12 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 1, 2, 3, 5] {
                    let mut got = mi.search(&q, tau);
                    got.sort();
                    let expect: Vec<u32> = (0..rows.len())
                        .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                        .map(|i| i as u32)
                        .collect();
                    assert_eq!(got, expect, "m={m} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn stats_are_consistent() {
        let rows = clustered_rows(2, 16, 400, 53);
        let set = SketchSet::from_rows(2, 16, &rows);
        let mi = MultiBst::build(&set, 2);
        let (hits, stats) = mi.search_with_stats(&rows[0], 3);
        assert_eq!(stats.solutions, hits.len());
        assert!(stats.verified >= stats.solutions);
        assert!(stats.emitted >= stats.verified);
    }

    #[test]
    fn count_and_topk_match_search() {
        let rows = clustered_rows(2, 16, 500, 54);
        let set = SketchSet::from_rows(2, 16, &rows);
        let mi = MultiBst::build(&set, 2);
        for tau in [0usize, 2, 4] {
            let ids = mi.search(&rows[0], tau);
            assert_eq!(mi.count(&rows[0], tau), ids.len(), "tau={tau}");
        }
        // top-k within radius tau equals sorted brute force
        let tau = 4;
        let mut all: Vec<(usize, u32)> = (0..rows.len())
            .map(|i| (ham_chars(&rows[i], &rows[0]), i as u32))
            .filter(|&(d, _)| d <= tau)
            .collect();
        all.sort_unstable();
        for k in [1usize, 5, 50] {
            let got = mi.top_k(&rows[0], k, tau);
            let expect: Vec<(u32, usize)> =
                all.iter().take(k).map(|&(d, id)| (id, d)).collect();
            assert_eq!(got, expect, "k={k}");
        }
    }

    #[test]
    fn visited_epoch_wraps_safely() {
        let mut v = Visited::new(4);
        for _ in 0..5 {
            v.next_query();
            assert!(v.insert(2));
            assert!(!v.insert(2));
        }
        // Force wraparound.
        v.cur = u32::MAX;
        v.next_query();
        assert_eq!(v.cur, 1);
        assert!(v.insert(2));
    }

    #[test]
    fn duplicate_sketches_reported_once_each() {
        let mut rows = clustered_rows(2, 8, 100, 55);
        rows.push(rows[0].clone());
        rows.push(rows[0].clone());
        let set = SketchSet::from_rows(2, 8, &rows);
        let mi = MultiBst::build(&set, 2);
        let got = mi.search(&rows[0], 0);
        let dup_count = got
            .iter()
            .filter(|&&id| rows[id as usize] == rows[0])
            .count();
        assert_eq!(dup_count, got.len());
        // each id exactly once
        let set_ids: std::collections::HashSet<_> = got.iter().collect();
        assert_eq!(set_ids.len(), got.len());
    }
}
