//! Single-index approach backed by a trie (§IV / §VI-B).
//!
//! The trie *replaces* the inverted index: the similarity search traverses
//! it directly (no signature generation), so one structure serves every τ.
//! `SI-bST` is the paper's headline method; `SingleLouds` / `SingleFst`
//! are the Table III baselines behind the same interface.

use super::SearchIndex;
use crate::query::{Collector, QueryCtx};
use crate::sketch::SketchSet;
use crate::store::{ByteReader, ByteWriter, Persist, StoreError};
use crate::trie::bst::{BstConfig, BstTrie};
use crate::trie::fst::FstTrie;
use crate::trie::louds::LoudsTrie;
use crate::trie::pointer::PointerTrie;
use crate::trie::{SketchTrie, SortedSketches};

/// Generic single-index over any [`SketchTrie`].
pub struct SingleIndex<T: SketchTrie> {
    trie: T,
    label: &'static str,
}

impl<T: SketchTrie> SearchIndex for SingleIndex<T> {
    fn run(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector) {
        // `&mut dyn Collector` implements Collector (forwarding impl), so
        // the trie traversal monomorphizes over the dynamic adapter.
        let mut c = c;
        self.trie.run(q, ctx, &mut c);
    }

    fn run_block(
        &self,
        qs: &[&[u8]],
        ctx: &mut QueryCtx,
        bc: &mut crate::query::BlockCollector,
    ) {
        // bST descends once for the whole block; the other tries fall
        // back to the trait's per-query default.
        self.trie.run_block(qs, ctx, bc);
    }

    fn heap_bytes(&self) -> usize {
        self.trie.heap_bytes()
    }

    fn name(&self) -> String {
        self.label.to_string()
    }
}

impl<T: SketchTrie> SingleIndex<T> {
    pub fn trie(&self) -> &T {
        &self.trie
    }
}

/// A single-index snapshot is just its trie; the label is a compile-time
/// constant of the concrete alias, so each alias gets its own impl.
macro_rules! impl_persist_single {
    ($alias:ty, $trie:ty, $label:literal) => {
        impl Persist for $alias {
            fn write_into(&self, w: &mut ByteWriter) {
                self.trie.write_into(w);
            }

            fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
                Ok(SingleIndex { trie: <$trie>::read_from(r)?, label: $label })
            }
        }
    };
}

impl_persist_single!(SingleBst, BstTrie, "SI-bST");
impl_persist_single!(SingleLouds, LoudsTrie, "SI-LOUDS");
impl_persist_single!(SingleFst, FstTrie, "SI-FST");
impl_persist_single!(SinglePointer, PointerTrie, "SI-PT");

/// `SI-bST`: single-index over the b-bit sketch trie.
pub type SingleBst = SingleIndex<BstTrie>;

impl SingleBst {
    pub fn build(set: &SketchSet, cfg: BstConfig) -> Self {
        let ss = SortedSketches::build(set);
        SingleIndex { trie: BstTrie::build(&ss, cfg), label: "SI-bST" }
    }
}

/// Single-index over the LOUDS-trie baseline.
pub type SingleLouds = SingleIndex<LoudsTrie>;

impl SingleLouds {
    pub fn build(set: &SketchSet) -> Self {
        let ss = SortedSketches::build(set);
        SingleIndex { trie: LoudsTrie::build(&ss), label: "SI-LOUDS" }
    }
}

/// Single-index over the FST baseline.
pub type SingleFst = SingleIndex<FstTrie>;

impl SingleFst {
    pub fn build(set: &SketchSet) -> Self {
        let ss = SortedSketches::build(set);
        SingleIndex { trie: FstTrie::build(&ss), label: "SI-FST" }
    }
}

/// Single-index over the pointer trie (context rows / oracle).
pub type SinglePointer = SingleIndex<PointerTrie>;

impl SinglePointer {
    pub fn build(set: &SketchSet) -> Self {
        let ss = SortedSketches::build(set);
        SingleIndex { trie: PointerTrie::build(&ss), label: "SI-PT" }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn all_single_indexes_agree() {
        let mut rng = Rng::new(41);
        let rows: Vec<Vec<u8>> = (0..700)
            .map(|_| (0..12).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 12, &rows);
        let bst = SingleBst::build(&set, BstConfig::default());
        let louds = SingleLouds::build(&set);
        let fst = SingleFst::build(&set);
        let pt = SinglePointer::build(&set);
        for _ in 0..10 {
            let q: Vec<u8> = (0..12).map(|_| rng.below(4) as u8).collect();
            for tau in [0usize, 1, 3] {
                let mut a = bst.search(&q, tau);
                let mut b = louds.search(&q, tau);
                let mut c = fst.search(&q, tau);
                let mut d = pt.search(&q, tau);
                a.sort();
                b.sort();
                c.sort();
                d.sort();
                assert_eq!(a, b);
                assert_eq!(a, c);
                assert_eq!(a, d);
            }
        }
    }

    #[test]
    fn bst_is_smallest() {
        let mut rng = Rng::new(43);
        let rows: Vec<Vec<u8>> = (0..4000)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 16, &rows);
        let bst = SingleBst::build(&set, BstConfig::default());
        let louds = SingleLouds::build(&set);
        let fst = SingleFst::build(&set);
        assert!(bst.heap_bytes() < louds.heap_bytes(), "bST must beat LOUDS");
        assert!(bst.heap_bytes() < fst.heap_bytes(), "bST must beat FST");
    }
}
