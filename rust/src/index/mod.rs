//! Similarity-search indexes.
//!
//! Every method of the paper's evaluation (§VI) behind one trait:
//!
//! | method       | approach     | filter backend        | module      |
//! |--------------|--------------|-----------------------|-------------|
//! | `SI-bST`     | single-index | bST traversal         | [`single`]  |
//! | `MI-bST`     | multi-index  | per-block bST         | [`multi`]   |
//! | `SIH`        | single-index | hash + signatures     | [`sih`]     |
//! | `MIH`        | multi-index  | per-block hash + sigs | [`mih`]     |
//! | `HmSearch`   | multi-index  | 1-var signatures in DB| [`hmsearch`]|
//! | linear scan  | none         | vertical Hamming      | [`linear`]  |
//!
//! Supporting machinery: [`signature`] (Hamming-ball enumeration),
//! [`hashdex`] (open-addressing inverted index on packed block keys),
//! [`blocks`] (multi-index partitioning + threshold assignment).

pub mod blocks;
pub mod hashdex;
pub mod hmsearch;
pub mod linear;
pub mod mih;
pub mod multi;
pub mod signature;
pub mod sih;
pub mod single;

pub use hmsearch::HmSearch;
pub use linear::LinearScan;
pub use mih::Mih;
pub use multi::MultiBst;
pub use sih::Sih;
pub use single::{SingleBst, SingleFst, SingleLouds};

/// A Hamming-threshold similarity index over a fixed sketch database.
pub trait SearchIndex {
    /// Ids of all sketches with `ham(s_i, q) <= tau`, in unspecified order.
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32>;

    /// Heap bytes owned by the index (paper Tables III/IV).
    fn heap_bytes(&self) -> usize;

    /// Display name matching the paper's method labels.
    fn name(&self) -> String;

    /// Largest threshold the index supports (`None` = unlimited).
    /// HmSearch is built per-τ-bucket; others accept any τ.
    fn max_tau(&self) -> Option<usize> {
        None
    }
}
