//! Similarity-search indexes.
//!
//! Every method of the paper's evaluation (§VI) behind one trait:
//!
//! | method       | approach     | filter backend        | module      |
//! |--------------|--------------|-----------------------|-------------|
//! | `SI-bST`     | single-index | bST traversal         | [`single`]  |
//! | `MI-bST`     | multi-index  | per-block bST         | [`multi`]   |
//! | `SIH`        | single-index | hash + signatures     | [`sih`]     |
//! | `MIH`        | multi-index  | per-block hash + sigs | [`mih`]     |
//! | `HmSearch`   | multi-index  | 1-var signatures in DB| [`hmsearch`]|
//! | linear scan  | none         | vertical Hamming      | [`linear`]  |
//!
//! The primary entry point is [`SearchIndex::run`]: every index executes
//! a query against a caller-supplied [`Collector`] (ids / count / top-k /
//! stats — see [`crate::query`]) with reusable [`QueryCtx`] scratch. The
//! collector carries the threshold; because [`crate::query::TopK`]
//! tightens it while candidates stream in, every index answers
//! nearest-neighbor queries through the same code path that serves
//! threshold queries. [`SearchIndex::search`] / [`SearchIndex::count`] /
//! [`SearchIndex::top_k`] are thin wrappers over `run`.
//!
//! `run` takes `&mut dyn Collector` (not a generic parameter) so the
//! trait stays object-safe — the sharded engine stores
//! `Box<dyn SearchIndex>` per shard. Trie traversals underneath are
//! still monomorphized; only the per-group `emit` crosses a vtable.
//!
//! Supporting machinery: [`signature`] (Hamming-ball enumeration),
//! [`hashdex`] (open-addressing inverted index on packed block keys),
//! [`blocks`] (multi-index partitioning + threshold assignment).

pub mod blocks;
pub mod hashdex;
pub mod hmsearch;
pub mod linear;
pub mod mih;
pub mod multi;
pub mod signature;
pub mod sih;
pub mod single;

pub use hmsearch::HmSearch;
pub use linear::LinearScan;
pub use mih::Mih;
pub use multi::MultiBst;
pub use sih::Sih;
pub use single::{SingleBst, SingleFst, SingleLouds};

use crate::query::{BlockCollector, CollectIds, Collector, CountOnly, QueryCtx, SlotRef, TopK};

/// A Hamming-threshold similarity index over a fixed sketch database.
pub trait SearchIndex {
    /// Executes a query, feeding every solution (with its exact distance)
    /// to the collector. The collector's `tau()` at entry is the τ the
    /// index plans for; adaptive collectors may tighten it mid-query.
    fn run(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector);

    /// Executes a whole query block (slot `j` of `bc` is query `j`'s
    /// collector) in one call. Indexes with a native blocked path share
    /// one pass over their data structures; the default falls back to
    /// one serial `run` per query, routed through the block collector so
    /// per-query results, stats and work attribution are uniform either
    /// way. Results and per-query `TraversalStats` are identical to
    /// serial execution by contract.
    fn run_block(&self, qs: &[&[u8]], ctx: &mut QueryCtx, bc: &mut BlockCollector) {
        assert_eq!(qs.len(), bc.len(), "query block / collector slot mismatch");
        for (j, q) in qs.iter().enumerate() {
            let mut slot = SlotRef::new(bc, j);
            self.run(q, ctx, &mut slot);
        }
    }

    /// Ids of all sketches with `ham(s_i, q) <= tau`, in unspecified order.
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        let mut out = Vec::new();
        let mut ctx = QueryCtx::new();
        let mut coll = CollectIds::new(tau, &mut out);
        self.run(q, &mut ctx, &mut coll);
        out
    }

    /// Number of sketches with `ham(s_i, q) <= tau`.
    fn count(&self, q: &[u8], tau: usize) -> usize {
        let mut ctx = QueryCtx::new();
        let mut coll = CountOnly::new(tau);
        self.run(q, &mut ctx, &mut coll);
        coll.count()
    }

    /// The `k` nearest sketches within radius `tau`, sorted by
    /// `(dist, id)` and returned as `(id, dist)` pairs. Pass `tau = L`
    /// for an unbounded nearest-neighbor query.
    fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        let mut ctx = QueryCtx::new();
        let mut out = Vec::new();
        self.top_k_into(q, k, tau, &mut ctx, &mut out);
        out
    }

    /// Reusable-scratch form of [`SearchIndex::top_k`]: the adaptive heap
    /// is parked in `ctx` between queries and `out` is cleared and
    /// refilled, so steady-state top-k traffic over a warm ctx performs
    /// no heap allocation (enforced by `tests/query_alloc.rs`).
    fn top_k_into(
        &self,
        q: &[u8],
        k: usize,
        tau: usize,
        ctx: &mut QueryCtx,
        out: &mut Vec<(u32, usize)>,
    ) {
        let mut coll = TopK::with_heap(k, tau, ctx.take_topk_heap());
        self.run(q, ctx, &mut coll);
        coll.drain_into(out);
        ctx.put_topk_heap(coll.into_heap());
    }

    /// Heap bytes owned by the index (paper Tables III/IV).
    fn heap_bytes(&self) -> usize;

    /// Display name matching the paper's method labels.
    fn name(&self) -> String;

    /// Largest threshold the index supports (`None` = unlimited).
    /// HmSearch is built per-τ-bucket; others accept any τ.
    fn max_tau(&self) -> Option<usize> {
        None
    }
}
