//! SIH — single-index hashing (§III-A).
//!
//! An inverted index keyed by the *whole* sketch; a query enumerates every
//! signature in its Hamming ball (Eq. 3) and probes each. Cost explodes as
//! `Σ C(L,k)(2^b−1)^k` — the paper caps SIH at 10 s per query and reports
//! timeouts for larger τ/b (Fig. 7); [`Sih::search_capped`] reproduces
//! that cap.
//!
//! Sketches with `L·b <= 64` use exact packed keys; longer sketches
//! (GIST: 512 bits) use a 64-bit mixed key plus full verification of the
//! retrieved candidates (collision-safe, and the extra check is free
//! relative to enumeration).
//!
//! Blocked execution: SIH's cost is signature *enumeration*, whose ball
//! depends on each query's own sketch and τ — there is no shared data
//! pass to amortize — so `SearchIndex::run_block` keeps the trait's
//! per-query fallback (routed through the block collector, which keeps
//! work attribution and stats uniform with the blocked indexes).

use super::hashdex::HashIndex;
use super::signature::{for_each_signature, pack_key};
use super::SearchIndex;
use crate::query::{CollectIds, Collector, QueryCtx};
use crate::sketch::{SketchSet, VerticalSet};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::rng::mix64;
use crate::util::HeapSize;
use std::time::{Duration, Instant};

/// Single-index hashing over whole sketches.
pub struct Sih {
    index: HashIndex,
    b: usize,
    l: usize,
    /// Exact packed keys (fits in u64) or mixed hash keys.
    exact_keys: bool,
    /// Verification store (only consulted when `exact_keys` is false).
    vertical: Option<VerticalSet>,
}

/// Result of a capped search.
pub enum CappedResult {
    Done(Vec<u32>),
    /// The per-query time budget expired mid-enumeration.
    TimedOut,
}

/// Mixes an arbitrary-width packed row into a 64-bit key.
#[inline]
fn mixed_key(row: &[u8], b: usize) -> u64 {
    // fold 64-bit chunks of the packed representation
    let mut h = 0x9E37_79B9_7F4A_7C15u64 ^ (row.len() as u64);
    let mut acc = 0u64;
    let mut bits = 0usize;
    for &c in row {
        acc = (acc << b) | c as u64;
        bits += b;
        if bits >= 56 {
            h = mix64(h ^ acc);
            acc = 0;
            bits = 0;
        }
    }
    if bits > 0 {
        h = mix64(h ^ acc);
    }
    h
}

impl Sih {
    pub fn build(set: &SketchSet) -> Self {
        let (b, l, n) = (set.b(), set.l(), set.n());
        let exact_keys = l * b <= 64;
        let key_of = |row: &[u8]| -> u64 {
            if exact_keys {
                pack_key(row, b)
            } else {
                mixed_key(row, b)
            }
        };
        let index = HashIndex::build(n, || {
            (0..n).map(|i| (key_of(&set.row(i)), i as u32))
        });
        let vertical = (!exact_keys).then(|| VerticalSet::from_horizontal(set));
        Sih { index, b, l, exact_keys, vertical }
    }

    #[inline]
    fn key_of(&self, row: &[u8]) -> u64 {
        if self.exact_keys {
            pack_key(row, self.b)
        } else {
            mixed_key(row, self.b)
        }
    }

    /// Search with the paper's per-query wall-clock cap (10 s in §VI-C).
    ///
    /// Signature enumeration is *not* materialized: each signature probes
    /// the index as it is generated, checking the clock every 4096
    /// signatures.
    pub fn search_capped(&self, q: &[u8], tau: usize, budget: Duration) -> CappedResult {
        let mut out = Vec::new();
        let mut coll = CollectIds::new(tau, &mut out);
        if self.run_capped(q, tau, budget, &mut coll) {
            CappedResult::Done(out)
        } else {
            CappedResult::TimedOut
        }
    }

    /// Core enumeration loop feeding a collector; returns `false` on
    /// timeout. `tau` fixes the enumeration ball (signature generation
    /// cannot shrink mid-flight), but candidate emission respects the
    /// collector's live threshold.
    fn run_capped(
        &self,
        q: &[u8],
        tau: usize,
        budget: Duration,
        c: &mut dyn Collector,
    ) -> bool {
        assert_eq!(q.len(), self.l);
        let start = Instant::now();
        let q_planes = self.vertical.as_ref().map(|v| v.pack_query(q));
        let mut since_check = 0usize;
        let mut timed_out = false;

        let completed = if self.exact_keys {
            // enumerate signatures directly as packed keys; an exact-key
            // hit's distance is the signature's edit count
            for_each_signature(q, self.b, tau, &mut |key, edits| {
                let ids = self.index.get(key);
                if !ids.is_empty() && edits <= c.tau() {
                    c.emit(ids, edits);
                }
                since_check += 1;
                if since_check >= 4096 {
                    since_check = 0;
                    if start.elapsed() > budget {
                        timed_out = true;
                        return false;
                    }
                }
                true
            })
        } else {
            // enumerate signature *rows*, mix each into a key, and verify
            // each key's posting list through the batched kernel
            let vertical = self.vertical.as_ref().unwrap();
            let q_planes = q_planes.as_ref().unwrap();
            let mut row = q.to_vec();
            self.enumerate_rows_capped(&mut row, 0, tau, &mut |r| {
                let key = self.key_of(r);
                // Posting lists are sorted ascending (built id-major,
                // validated on load), so the kernel streams monotone ids.
                let ids = self.index.get(key);
                if !ids.is_empty() {
                    vertical.ham_many_leq(ids, q_planes, c.tau(), |id, verdict| {
                        if let Some(d) = verdict {
                            c.emit(&[id], d);
                        }
                        Some(c.tau())
                    });
                }
                since_check += 1;
                if since_check >= 4096 {
                    since_check = 0;
                    if start.elapsed() > budget {
                        timed_out = true;
                        return false;
                    }
                }
                true
            })
        };
        completed && !timed_out
    }

    /// DFS over signature rows in place (mirrors
    /// [`super::signature::for_each_signature`] but yields `&[u8]`).
    fn enumerate_rows_capped(
        &self,
        row: &mut Vec<u8>,
        from: usize,
        budget: usize,
        f: &mut dyn FnMut(&[u8]) -> bool,
    ) -> bool {
        if from == 0 && !f(row) {
            return false;
        }
        if budget == 0 {
            return true;
        }
        let sigma = 1u8 << self.b;
        for pos in from..self.l {
            let orig = row[pos];
            for c in 0..sigma {
                if c == orig {
                    continue;
                }
                row[pos] = c;
                if !f(row) {
                    row[pos] = orig;
                    return false;
                }
                if budget > 1 && !self.enumerate_rows_capped(row, pos + 1, budget - 1, f) {
                    row[pos] = orig;
                    return false;
                }
            }
            row[pos] = orig;
        }
        true
    }
}

impl Persist for Sih {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.l);
        w.put_u8(self.exact_keys as u8);
        self.index.write_into(w);
        match &self.vertical {
            Some(v) => {
                w.put_u8(1);
                v.write_into(w);
            }
            None => w.put_u8(0),
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let l = r.get_usize()?;
        let exact_keys = r.get_u8()? != 0;
        let index = HashIndex::read_from(r)?;
        let vertical = if r.get_u8()? != 0 {
            Some(VerticalSet::read_from(r)?)
        } else {
            None
        };
        // bound L before the l*b products below (debug-overflow safety).
        ensure(matches!(b, 1 | 2 | 4 | 8) && l >= 1 && l <= 64 * 64, || {
            format!("SIH: bad dims b={b} L={l}")
        })?;
        ensure(exact_keys == (l * b <= 64), || {
            "SIH: key scheme disagrees with sketch shape".to_string()
        })?;
        // Mixed keys collide; the verification store is mandatory there.
        ensure(exact_keys == vertical.is_none(), || {
            "SIH: verification store presence disagrees with key scheme".to_string()
        })?;
        if let Some(v) = &vertical {
            ensure(v.b() == b && v.l() == l, || {
                "SIH: verification store shape mismatch".to_string()
            })?;
            // Mixed-key hits are verified by indexing the store — bound
            // the ids at load so a crafted table cannot read out of range.
            ensure(index.max_posting().map_or(true, |m| (m as usize) < v.n()), || {
                format!("SIH: postings exceed the {}-row verification store", v.n())
            })?;
        }
        Ok(Sih { index, b, l, exact_keys, vertical })
    }
}

impl SearchIndex for Sih {
    fn run(&self, q: &[u8], _ctx: &mut QueryCtx, c: &mut dyn Collector) {
        // Uncapped (tests, small τ); serving paths use `search_capped`.
        let _ = self.run_capped(q, c.tau(), Duration::from_secs(u64::MAX / 2), c);
    }

    fn heap_bytes(&self) -> usize {
        self.index.heap_bytes()
            + self.vertical.as_ref().map_or(0, |v| v.heap_bytes())
    }

    fn name(&self) -> String {
        "SIH".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect()
    }

    #[test]
    fn matches_linear_scan_exact_keys() {
        let rows = rows(2, 10, 600, 61);
        let set = SketchSet::from_rows(2, 10, &rows);
        let sih = Sih::build(&set);
        assert!(sih.exact_keys);
        let mut rng = Rng::new(62);
        for _ in 0..10 {
            let q = rows[rng.below_usize(rows.len())].clone();
            for tau in [0usize, 1, 2] {
                let mut got = sih.search(&q, tau);
                got.sort();
                got.dedup();
                let expect: Vec<u32> = (0..rows.len())
                    .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect, "tau={tau}");
            }
        }
    }

    #[test]
    fn matches_linear_scan_mixed_keys() {
        // b=8, L=12 → 96 bits: mixed-key path with verification.
        let rows = rows(8, 12, 300, 63);
        let set = SketchSet::from_rows(8, 12, &rows);
        let sih = Sih::build(&set);
        assert!(!sih.exact_keys);
        let q = rows[5].clone();
        for tau in [0usize, 1] {
            let mut got = sih.search(&q, tau);
            got.sort();
            got.dedup();
            let expect: Vec<u32> = (0..rows.len())
                .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                .map(|i| i as u32)
                .collect();
            assert_eq!(got, expect, "tau={tau}");
        }
    }

    #[test]
    fn cap_triggers_on_tiny_budget() {
        let rows = rows(4, 16, 100, 65);
        let set = SketchSet::from_rows(4, 16, &rows);
        let sih = Sih::build(&set);
        // tau=4 over b=4,L=16 ≈ 2.8e9 sigs — must hit a 10ms budget.
        match sih.search_capped(&rows[0], 4, Duration::from_millis(10)) {
            CappedResult::TimedOut => {}
            CappedResult::Done(_) => panic!("expected timeout"),
        }
    }

    #[test]
    fn duplicate_sketches_all_reported() {
        let mut r = rows(2, 8, 50, 67);
        r.push(r[0].clone());
        let set = SketchSet::from_rows(2, 8, &r);
        let sih = Sih::build(&set);
        let got = sih.search(&r[0], 0);
        assert!(got.contains(&0) && got.contains(&50));
    }
}
