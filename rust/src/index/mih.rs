//! MIH — multi-index hashing (Norouzi et al., TPAMI 2014; §III-B).
//!
//! The multi-index framework with hash-table block filters: each block
//! keeps an inverted index keyed by the (packed or mixed) block value;
//! filtering enumerates the query block's signature ball at the block
//! threshold and probes each signature.
//!
//! Block keys are exact when `L_j · b <= 64` (every configuration in the
//! paper except GIST m=2..3, whose blocks are mixed-hashed; the
//! framework's verification step absorbs collisions soundly).

use super::hashdex::HashIndex;
use super::multi::{BlockFilter, BlockScratch, MultiIndex};
use super::signature::{for_each_signature, pack_key};
use crate::sketch::SketchSet;
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::rng::mix64;
use crate::util::HeapSize;

/// Hash-table inverted index over one block.
pub struct HashBlockFilter {
    index: HashIndex,
    b: usize,
    l: usize,
    exact_keys: bool,
}

#[inline]
fn mixed_key(row: &[u8], b: usize) -> u64 {
    let mut h = 0x517c_c1b7_2722_0a95u64 ^ (row.len() as u64);
    let mut acc = 0u64;
    let mut bits = 0usize;
    for &c in row {
        acc = (acc << b) | c as u64;
        bits += b;
        if bits >= 56 {
            h = mix64(h ^ acc);
            acc = 0;
            bits = 0;
        }
    }
    if bits > 0 {
        h = mix64(h ^ acc);
    }
    h
}

impl BlockFilter for HashBlockFilter {
    fn build(block: &SketchSet) -> Self {
        let (b, l, n) = (block.b(), block.l(), block.n());
        let exact_keys = l * b <= 64;
        let index = HashIndex::build(n, || {
            (0..n).map(|i| {
                let row = block.row(i);
                let key = if exact_keys {
                    pack_key(&row, b)
                } else {
                    mixed_key(&row, b)
                };
                (key, i as u32)
            })
        });
        HashBlockFilter { index, b, l, exact_keys }
    }

    fn candidates(
        &self,
        q_block: &[u8],
        tau_j: usize,
        scratch: &mut BlockScratch,
        emit: &mut dyn FnMut(u32),
    ) {
        debug_assert_eq!(q_block.len(), self.l);
        if self.exact_keys {
            for_each_signature(q_block, self.b, tau_j, &mut |key, _edits| {
                for &id in self.index.get(key) {
                    emit(id);
                }
                true
            });
        } else {
            // enumerate signature rows in place (in the shared scratch
            // buffer), probing the mixed key of each
            let row = &mut scratch.row;
            row.clear();
            row.extend_from_slice(q_block);
            enumerate_rows(row, self.b, 0, tau_j, true, &mut |r| {
                for &id in self.index.get(mixed_key(r, self.b)) {
                    emit(id);
                }
            });
        }
    }

    fn heap_bytes(&self) -> usize {
        self.index.heap_bytes()
    }

    fn filter_name() -> &'static str {
        "MIH"
    }

    fn block_len(&self) -> usize {
        self.l
    }

    fn max_id(&self) -> Option<u32> {
        self.index.max_posting()
    }

    fn alphabet_bits(&self) -> usize {
        self.b
    }
}

impl Persist for HashBlockFilter {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.l);
        w.put_u8(self.exact_keys as u8);
        self.index.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let l = r.get_usize()?;
        let exact_keys = r.get_u8()? != 0;
        let index = HashIndex::read_from(r)?;
        // bound L before the l*b product below (debug-overflow safety).
        ensure((1..=8).contains(&b) && l >= 1 && l <= 64 * 64, || {
            format!("MIH block: bad dims b={b} L={l}")
        })?;
        // The key scheme is a pure function of the block shape.
        ensure(exact_keys == (l * b <= 64), || {
            "MIH block: key scheme disagrees with block shape".to_string()
        })?;
        Ok(HashBlockFilter { index, b, l, exact_keys })
    }
}

/// In-place DFS over the signature rows of `row` within `budget` edits.
pub(crate) fn enumerate_rows(
    row: &mut Vec<u8>,
    b: usize,
    from: usize,
    budget: usize,
    include_self: bool,
    f: &mut dyn FnMut(&[u8]),
) {
    if include_self {
        f(row);
    }
    if budget == 0 {
        return;
    }
    let sigma = 1u8 << b;
    let l = row.len();
    for pos in from..l {
        let orig = row[pos];
        for c in 0..sigma {
            if c == orig {
                continue;
            }
            row[pos] = c;
            f(row);
            if budget > 1 {
                enumerate_rows(row, b, pos + 1, budget - 1, false, f);
            }
        }
        row[pos] = orig;
    }
}

/// `MIH`: the multi-index with hash-table filters.
pub type Mih = MultiIndex<HashBlockFilter>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::SearchIndex;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn clustered(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..12)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut row = centers[rng.below_usize(12)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(l);
                    row[p] = rng.below(1 << b) as u8;
                }
                row
            })
            .collect()
    }

    #[test]
    fn matches_linear_scan() {
        let rows = clustered(2, 16, 800, 71);
        let set = SketchSet::from_rows(2, 16, &rows);
        let mut rng = Rng::new(72);
        for m in [2usize, 3, 4] {
            let mih = Mih::build(&set, m);
            for _ in 0..8 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 1, 2, 4, 5] {
                    let mut got = mih.search(&q, tau);
                    got.sort();
                    let expect: Vec<u32> = (0..rows.len())
                        .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                        .map(|i| i as u32)
                        .collect();
                    assert_eq!(got, expect, "m={m} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn mixed_key_blocks_gist_shape() {
        // b=8, L=16, m=2 → 8-char blocks = 64 bits exact; m=1 block of 16
        // chars = 128 bits → mixed. Force the mixed path via m=1.
        let rows = clustered(8, 16, 300, 73);
        let set = SketchSet::from_rows(8, 16, &rows);
        let mih = Mih::build(&set, 1);
        let q = rows[3].clone();
        for tau in [0usize, 1] {
            let mut got = mih.search(&q, tau);
            got.sort();
            let expect: Vec<u32> = (0..rows.len())
                .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                .map(|i| i as u32)
                .collect();
            assert_eq!(got, expect, "tau={tau}");
        }
    }

    #[test]
    fn enumerate_rows_ball_size() {
        let mut row = vec![0u8, 1, 2];
        let mut count = 0usize;
        enumerate_rows(&mut row, 2, 0, 2, true, &mut |_| count += 1);
        // 1 + 3*3 + C(3,2)*9 = 37
        assert_eq!(count, 37);
        assert_eq!(row, vec![0, 1, 2], "row restored");
    }
}
