//! Open-addressing inverted index: packed sketch/block key → id postings.
//!
//! The hash-table backend of SIH / MIH / HmSearch (§III). `std::HashMap`
//! would work, but an explicit structure gives (a) honest memory
//! accounting for the paper's space tables, (b) postings grouped in one
//! arena rather than per-key `Vec`s, (c) ~2× faster probes (no SipHash).
//!
//! Layout: robin-hood-free linear probing over `(key+1)`-tagged slots
//! (0 = empty), two-pass construction (count, then fill) so postings of a
//! key are contiguous in one arena.

use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, U32s, Words};
use crate::util::rng::mix64;
use crate::util::HeapSize;

const EMPTY: u64 = 0;

/// Immutable key → postings-list map built from `(key, id)` pairs.
///
/// The two-pass builder iterates pairs id-major, so every posting list is
/// sorted ascending by construction; `read_from` validates this so loaded
/// indexes can hand raw lists straight to the monotone-streaming
/// verification kernels.
pub struct HashIndex {
    /// Tagged keys (`key + 1`; 0 = empty slot). Power-of-two length.
    slots: Words,
    /// Postings range of slot `s`: `arena[starts[s]..starts[s+1]]` —
    /// `starts` is indexed by *slot*, `u32::MAX` sentinel for empty.
    offsets: U32s,
    lens: U32s,
    arena: U32s,
    n_keys: usize,
}

impl HashIndex {
    /// Builds from an iterator of `(key, id)` pairs supplied twice (the
    /// builder runs two passes).
    pub fn build<I, F>(n_pairs: usize, mut pairs: F) -> Self
    where
        I: Iterator<Item = (u64, u32)>,
        F: FnMut() -> I,
    {
        // Load factor 0.5 (power of two).
        let cap = (n_pairs.max(1) * 2).next_power_of_two();
        let mut slots = vec![EMPTY; cap];
        let mut lens = vec![0u32; cap];
        let mask = cap - 1;

        // Pass 1: insert keys, count postings per slot.
        let mut n_keys = 0usize;
        for (key, _) in pairs() {
            let tagged = key.wrapping_add(1);
            debug_assert_ne!(tagged, EMPTY, "key u64::MAX unsupported");
            let mut s = (mix64(key) as usize) & mask;
            loop {
                if slots[s] == EMPTY {
                    slots[s] = tagged;
                    n_keys += 1;
                    lens[s] += 1;
                    break;
                }
                if slots[s] == tagged {
                    lens[s] += 1;
                    break;
                }
                s = (s + 1) & mask;
            }
        }

        // Prefix-sum into offsets.
        let mut offsets = vec![0u32; cap + 1];
        let mut acc = 0u32;
        for s in 0..cap {
            offsets[s] = acc;
            acc += lens[s];
        }
        offsets[cap] = acc;
        debug_assert_eq!(acc as usize, n_pairs);

        // Pass 2: fill the arena.
        let mut arena = vec![0u32; n_pairs];
        let mut cursor = offsets[..cap].to_vec();
        for (key, id) in pairs() {
            let tagged = key.wrapping_add(1);
            let mut s = (mix64(key) as usize) & mask;
            while slots[s] != tagged {
                s = (s + 1) & mask;
            }
            arena[cursor[s] as usize] = id;
            cursor[s] += 1;
        }

        HashIndex {
            slots: slots.into(),
            offsets: offsets.into(),
            lens: lens.into(),
            arena: arena.into(),
            n_keys,
        }
    }

    /// Number of distinct keys.
    pub fn n_keys(&self) -> usize {
        self.n_keys
    }

    /// Postings for `key` (empty slice if absent).
    #[inline]
    pub fn get(&self, key: u64) -> &[u32] {
        let tagged = key.wrapping_add(1);
        let mask = self.slots.len() - 1;
        let mut s = (mix64(key) as usize) & mask;
        loop {
            let slot = self.slots[s];
            if slot == tagged {
                let lo = self.offsets[s] as usize;
                return &self.arena[lo..lo + self.lens[s] as usize];
            }
            if slot == EMPTY {
                return &[];
            }
            s = (s + 1) & mask;
        }
    }

    /// Total stored postings.
    pub fn n_postings(&self) -> usize {
        self.arena.len()
    }

    /// Largest stored posting id (`None` when empty) — snapshot loaders
    /// use this to bound ids against the database size they serve.
    pub fn max_posting(&self) -> Option<u32> {
        self.arena.iter().copied().max()
    }
}

impl Persist for HashIndex {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_u64s(&self.slots);
        w.put_u32s(&self.offsets);
        w.put_u32s(&self.lens);
        w.put_u32s(&self.arena);
        w.put_usize(self.n_keys);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let slots = r.get_u64s_ref()?;
        let offsets = r.get_u32s_ref()?;
        let lens = r.get_u32s_ref()?;
        let arena = r.get_u32s_ref()?;
        let n_keys = r.get_usize()?;
        let cap = slots.len();
        ensure(cap >= 1 && cap.is_power_of_two(), || {
            format!("HashIndex: capacity {cap} not a power of two")
        })?;
        ensure(offsets.len() == cap + 1 && lens.len() == cap, || {
            format!("HashIndex: table arrays disagree with capacity {cap}")
        })?;
        // offsets must be the exact prefix sums of lens over the arena —
        // in u64 so no wrapped chain can sneak a postings range past the
        // arena bounds (get() slices without re-checking).
        ensure(offsets[0] == 0 && offsets[cap] as usize == arena.len(), || {
            "HashIndex: offsets do not cover the arena".to_string()
        })?;
        for s in 0..cap {
            ensure(
                offsets[s] as u64 + lens[s] as u64 == offsets[s + 1] as u64,
                || format!("HashIndex: offsets[{s}] inconsistent with lens"),
            )?;
        }
        let occupied = slots.iter().filter(|&&s| s != EMPTY).count();
        ensure(occupied == n_keys, || {
            format!("HashIndex: {occupied} occupied slots, stored n_keys={n_keys}")
        })?;
        // At least one EMPTY slot, or probe loops on absent keys never end.
        ensure(n_keys < cap, || "HashIndex: table has no empty slot".to_string())?;
        for s in 0..cap {
            ensure(slots[s] != EMPTY || lens[s] == 0, || {
                format!("HashIndex: empty slot {s} has postings")
            })?;
        }
        // Every posting list must be sorted ascending (the builder's
        // id-major passes guarantee it); query paths stream raw lists
        // into the verification kernels assuming monotone ids.
        for s in 0..cap {
            let lo = offsets[s] as usize;
            let list = &arena[lo..lo + lens[s] as usize];
            ensure(list.windows(2).all(|w| w[0] <= w[1]), || {
                format!("HashIndex: postings of slot {s} are not sorted")
            })?;
        }
        Ok(HashIndex { slots, offsets, lens, arena, n_keys })
    }
}

impl HeapSize for HashIndex {
    fn heap_bytes(&self) -> usize {
        self.slots.heap_bytes()
            + self.offsets.heap_bytes()
            + self.lens.heap_bytes()
            + self.arena.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::HashMap;

    #[test]
    fn matches_std_hashmap() {
        let mut rng = Rng::new(1);
        let pairs: Vec<(u64, u32)> = (0..5000)
            .map(|i| (rng.below(700), i as u32))
            .collect();
        let idx = HashIndex::build(pairs.len(), || pairs.iter().copied());
        let mut reference: HashMap<u64, Vec<u32>> = HashMap::new();
        for &(k, v) in &pairs {
            reference.entry(k).or_default().push(v);
        }
        assert_eq!(idx.n_keys(), reference.len());
        assert_eq!(idx.n_postings(), pairs.len());
        for (k, expect) in &reference {
            let mut got = idx.get(*k).to_vec();
            got.sort();
            let mut expect = expect.clone();
            expect.sort();
            assert_eq!(&got, &expect, "key {k}");
        }
        // absent keys
        for k in 10_000..10_050u64 {
            assert!(idx.get(k).is_empty());
        }
    }

    #[test]
    fn single_pair() {
        let pairs = [(42u64, 7u32)];
        let idx = HashIndex::build(1, || pairs.iter().copied());
        assert_eq!(idx.get(42), &[7]);
        assert!(idx.get(41).is_empty());
    }

    #[test]
    fn adversarial_colliding_keys() {
        // keys differing only in high bits — mix64 must spread them.
        let pairs: Vec<(u64, u32)> =
            (0..1000).map(|i| ((i as u64) << 48, i as u32)).collect();
        let idx = HashIndex::build(pairs.len(), || pairs.iter().copied());
        for &(k, v) in &pairs {
            assert_eq!(idx.get(k), &[v]);
        }
    }

    #[test]
    fn empty_index() {
        let idx = HashIndex::build(0, || std::iter::empty());
        assert_eq!(idx.n_keys(), 0);
        assert!(idx.get(0).is_empty());
    }
}
