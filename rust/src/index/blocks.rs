//! Multi-index block partitioning and threshold assignment (§III-B).
//!
//! A sketch of length `L` is split into `m` disjoint blocks of near-equal
//! length (`⌊L/m⌋`, the first `L mod m` blocks one longer — matching MIH).
//!
//! **Per-block thresholds.** With `τ' = ⌊τ/m⌋` and `a = τ mod m`, the
//! tight general-pigeonhole split assigns `τ'` to the first `a+1` blocks
//! and `τ' − 1` to the rest:
//! if `Σ d_j <= τ` but block `j` exceeds its threshold for every `j`,
//! then `Σ d_j >= (a+1)(τ'+1) + (m−a−1)τ' = mτ' + a + 1 = τ + 1` —
//! contradiction. Blocks whose threshold would be negative need no lookup
//! at all.
//!
//! **Paper-text note.** §III-B states the assignment *reversed*
//! (`τ'−1` to the first `a+1` blocks, `τ'` to the rest), which admits
//! false negatives — e.g. `m=2, τ=3` gives thresholds `(0,1)` and misses
//! the distance split `d=(1,2)`. We implement the sound rule above; the
//! property test `no_false_negatives` pins it down.

/// The half-open character ranges of the `m` blocks.
pub fn block_ranges(l: usize, m: usize) -> Vec<(usize, usize)> {
    assert!(m >= 1 && m <= l, "need 1 <= m <= L");
    let base = l / m;
    let extra = l % m;
    let mut out = Vec::with_capacity(m);
    let mut lo = 0usize;
    for j in 0..m {
        let len = base + usize::from(j < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, l);
    out
}

/// Per-block thresholds for query threshold `tau`; `None` = the block
/// needs no candidate lookup (its threshold would be negative).
pub fn block_thresholds(tau: usize, m: usize) -> Vec<Option<usize>> {
    let tp = tau / m;
    let a = tau % m;
    (0..m)
        .map(|j| {
            if j <= a {
                Some(tp)
            } else {
                tp.checked_sub(1)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ranges_tile_and_balance() {
        for l in 1..=64usize {
            for m in 1..=l.min(8) {
                let r = block_ranges(l, m);
                assert_eq!(r.len(), m);
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, l);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                let lens: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let min = lens.iter().min().unwrap();
                let max = lens.iter().max().unwrap();
                assert!(max - min <= 1, "l={l} m={m} lens={lens:?}");
            }
        }
    }

    #[test]
    fn thresholds_sum_rule() {
        // Σ (θ_j + 1) must exceed τ (that's exactly the pigeonhole).
        for tau in 0..20usize {
            for m in 1..=6usize {
                let th = block_thresholds(tau, m);
                let total: usize = th.iter().map(|t| t.map_or(0, |x| x + 1)).sum();
                assert!(total >= tau + 1, "tau={tau} m={m} th={th:?}");
            }
        }
    }

    /// The defining property: any distance vector summing to <= tau is
    /// caught by at least one block at its threshold.
    #[test]
    fn no_false_negatives() {
        let mut rng = Rng::new(7);
        for _ in 0..20_000 {
            let m = 1 + rng.below_usize(5);
            let tau = rng.below_usize(12);
            let th = block_thresholds(tau, m);
            // random split of some total <= tau over m blocks
            let total = rng.below_usize(tau + 1);
            let mut d = vec![0usize; m];
            for _ in 0..total {
                d[rng.below_usize(m)] += 1;
            }
            let caught = (0..m).any(|j| th[j].is_some_and(|t| d[j] <= t));
            assert!(caught, "m={m} tau={tau} d={d:?} th={th:?}");
        }
    }

    /// Regression: the paper's stated (reversed) assignment is unsound.
    #[test]
    fn papers_reversed_rule_would_miss() {
        // m=2, tau=3: paper's text gives (0, 1); d=(1,2) sums to 3 but
        // 1 > 0 and 2 > 1 — missed. Our rule gives (1, 1): caught.
        let ours = block_thresholds(3, 2);
        assert_eq!(ours, vec![Some(1), Some(1)]);
        let d = [1usize, 2];
        assert!((0..2).any(|j| ours[j].is_some_and(|t| d[j] <= t)));
    }

    #[test]
    fn small_tau_skips_blocks() {
        // tau=1, m=3: thresholds (0, 0, None) wait — a=1 → blocks 0,1 get
        // tp=0, block 2 gets None.
        assert_eq!(block_thresholds(1, 3), vec![Some(0), Some(0), None]);
        assert_eq!(block_thresholds(0, 2), vec![Some(0), None]);
        assert_eq!(block_thresholds(5, 2), vec![Some(2), Some(2)]);
        assert_eq!(block_thresholds(4, 2), vec![Some(2), Some(1)]);
    }
}
