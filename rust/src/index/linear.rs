//! Linear scan baseline: bit-parallel vertical Hamming over the whole
//! database. No index at all — the floor every filter method must beat,
//! and the ground-truth oracle of the test suite.

use super::SearchIndex;
use crate::query::{Collector, QueryCtx};
use crate::sketch::{SketchSet, VerticalSet};
use crate::store::{ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;

/// Brute-force scanner in vertical format.
pub struct LinearScan {
    vertical: VerticalSet,
}

impl LinearScan {
    pub fn build(set: &SketchSet) -> Self {
        LinearScan { vertical: VerticalSet::from_horizontal(set) }
    }

    /// Access to the underlying vertical database (shared with the XLA
    /// hamming-scan runtime path).
    pub fn vertical(&self) -> &VerticalSet {
        &self.vertical
    }
}

impl Persist for LinearScan {
    fn write_into(&self, w: &mut ByteWriter) {
        self.vertical.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        Ok(LinearScan { vertical: VerticalSet::read_from(r)? })
    }
}

impl SearchIndex for LinearScan {
    fn run(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector) {
        // Reuse the caller's plane scratch: the scan is allocation-free.
        self.vertical.pack_query_into(q, &mut ctx.q_planes);
        let n = self.vertical.n();
        // One streaming kernel call over the whole database: sequential
        // word loads with the b>1 lower-bound early exit, re-reading the
        // collector's live threshold per row. Every row is visited
        // exactly once and pruned-row counts are order-independent, so
        // both are accounted through the batched hooks (one virtual call
        // each instead of n).
        c.on_visit_many(n);
        let mut pruned = 0usize;
        self.vertical.ham_range_leq(0, n, &ctx.q_planes, c.tau(), |i, verdict| {
            match verdict {
                Some(d) => c.emit(&[i as u32], d),
                None => pruned += 1,
            }
            Some(c.tau())
        });
        c.on_prune_many(pruned);
    }

    fn run_block(
        &self,
        qs: &[&[u8]],
        ctx: &mut QueryCtx,
        bc: &mut crate::query::BlockCollector,
    ) {
        let m = bc.len();
        assert_eq!(qs.len(), m, "query block / collector slot mismatch");
        // Pack the whole block back to back, then stream the database
        // ONCE: each plane word is loaded one time and evaluated against
        // every query. Per-query accounting mirrors the serial scan
        // exactly — every row visited, prunes counted, the live tau
        // re-read per row — so results and stats are byte-identical.
        ctx.block_q.clear();
        for q in qs {
            self.vertical.pack_query_append(q, &mut ctx.block_q);
        }
        let n = self.vertical.n();
        let mut taus = [0usize; crate::query::MAX_BLOCK];
        for (j, t) in taus.iter_mut().take(m).enumerate() {
            bc.on_visit_many(j, n);
            *t = bc.tau(j);
        }
        let mut pruned = [0usize; crate::query::MAX_BLOCK];
        let live0 = crate::query::live_mask(m);
        self.vertical.ham_range_leq_multi(
            0,
            n,
            &ctx.block_q,
            &taus[..m],
            live0,
            |j, i, verdict| {
                match verdict {
                    Some(d) => bc.emit(j, &[i as u32], d),
                    None => pruned[j] += 1,
                }
                // The serial scan never stops early — it re-reads the
                // live threshold and keeps going, so no query is ever
                // dropped from the block here either.
                Some(bc.tau(j))
            },
        );
        for (j, &p) in pruned.iter().take(m).enumerate() {
            bc.on_prune_many(j, p);
        }
    }

    fn heap_bytes(&self) -> usize {
        self.vertical.heap_bytes()
    }

    fn name(&self) -> String {
        "LinearScan".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    #[test]
    fn finds_exact_neighbors() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<u8>> = (0..500)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 16, &rows);
        let scan = LinearScan::build(&set);
        for qi in [0usize, 10, 499] {
            for tau in [0usize, 2, 5] {
                let mut got = scan.search(&rows[qi], tau);
                got.sort();
                let expect: Vec<u32> = (0..rows.len())
                    .filter(|&i| ham_chars(&rows[i], &rows[qi]) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect);
            }
        }
    }
}
