//! Linear scan baseline: bit-parallel vertical Hamming over the whole
//! database. No index at all — the floor every filter method must beat,
//! and the ground-truth oracle of the test suite.

use super::SearchIndex;
use crate::sketch::{SketchSet, VerticalSet};
use crate::util::HeapSize;

/// Brute-force scanner in vertical format.
pub struct LinearScan {
    vertical: VerticalSet,
}

impl LinearScan {
    pub fn build(set: &SketchSet) -> Self {
        LinearScan { vertical: VerticalSet::from_horizontal(set) }
    }

    /// Access to the underlying vertical database (shared with the XLA
    /// hamming-scan runtime path).
    pub fn vertical(&self) -> &VerticalSet {
        &self.vertical
    }
}

impl SearchIndex for LinearScan {
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        self.vertical.scan(q, tau)
    }

    fn heap_bytes(&self) -> usize {
        self.vertical.heap_bytes()
    }

    fn name(&self) -> String {
        "LinearScan".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    #[test]
    fn finds_exact_neighbors() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<u8>> = (0..500)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 16, &rows);
        let scan = LinearScan::build(&set);
        for qi in [0usize, 10, 499] {
            for tau in [0usize, 2, 5] {
                let mut got = scan.search(&rows[qi], tau);
                got.sort();
                let expect: Vec<u32> = (0..rows.len())
                    .filter(|&i| ham_chars(&rows[i], &rows[qi]) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect);
            }
        }
    }
}
