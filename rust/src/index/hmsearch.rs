//! HmSearch (Zhang et al., SSDBM 2013; §III-B).
//!
//! The state-of-the-art pre-bST method for b-bit sketches. It partitions
//! sketches into `m = ⌊(τ_max+3)/2⌋` blocks so every block threshold is at
//! most 1 (if all blocks had distance ≥ 2, the total would be
//! `2m ≥ τ_max + 2 > τ_max`), and *pre-registers database-side signatures*
//! so the filter step needs only exact probes — trading memory for query
//! time, which is exactly the blow-up Table IV reports (it exceeded the
//! 256 GiB machine on SIFT).
//!
//! Signature scheme per block (both catch `d_j <= 1` with exact probes):
//! * `b <= 2` — **1-substitution**: register the block and all
//!   `L_j(2^b−1)` single-substitution variants; query probes its block.
//! * `b >= 4` — **1-deletion**: register the `L_j` position-tagged
//!   deletion variants (plus the block itself); query probes its own
//!   deletions. Far fewer signatures for large alphabets — the variant
//!   engineering the original uses for non-binary alphabets.
//!
//! Because `m` is a function of τ, an `HmSearch` instance serves
//! thresholds up to its `tau_max` only ([`SearchIndex::max_tau`]); the
//! eval harness builds one per τ-bucket exactly as the paper reports
//! (buckets τ∈{1,2}, {3,4}, {5}).

use super::blocks::block_ranges;
use super::hashdex::HashIndex;
use super::signature::pack_key;
use super::SearchIndex;
use crate::query::{Collector, QueryCtx};
use crate::sketch::{SketchSet, VerticalSet};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::rng::mix64;
use crate::util::HeapSize;
use std::sync::Mutex;

/// Which database-side signature scheme a block uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scheme {
    Substitution,
    Deletion,
}

struct Block {
    index: HashIndex,
    lo: usize,
    hi: usize,
    scheme: Scheme,
}

/// Reusable per-query probe state: the epoch-based visited set plus the
/// deduplicated candidate buffer of one probe (verified in a single
/// batched kernel call).
struct ProbeState {
    epochs: Vec<u32>,
    cur: u32,
    cands: Vec<u32>,
}

/// HmSearch index for thresholds `<= tau_max`.
pub struct HmSearch {
    blocks: Vec<Block>,
    b: usize,
    tau_max: usize,
    vertical: VerticalSet,
    state: Mutex<ProbeState>,
}

#[inline]
fn del_key(row: &[u8], skip: usize, b: usize) -> u64 {
    // position-tagged deletion key, mixed to 64 bits
    let mut h = mix64(0xD311_u64 ^ (skip as u64) << 8 ^ row.len() as u64);
    let mut acc = 0u64;
    let mut bits = 0usize;
    for (i, &c) in row.iter().enumerate() {
        if i == skip {
            continue;
        }
        acc = (acc << b) | c as u64;
        bits += b;
        if bits >= 56 {
            h = mix64(h ^ acc);
            acc = 0;
            bits = 0;
        }
    }
    if bits > 0 {
        h = mix64(h ^ acc);
    }
    h
}

#[inline]
fn sub_key(row: &[u8], b: usize) -> u64 {
    if row.len() * b <= 64 {
        pack_key(row, b)
    } else {
        let mut h = 0xAAAA_BBBB_CCCC_DDDDu64;
        let mut acc = 0u64;
        let mut bits = 0usize;
        for &c in row {
            acc = (acc << b) | c as u64;
            bits += b;
            if bits >= 56 {
                h = mix64(h ^ acc);
                acc = 0;
                bits = 0;
            }
        }
        if bits > 0 {
            h = mix64(h ^ acc);
        }
        h
    }
}

impl HmSearch {
    /// Number of blocks for a threshold bucket.
    pub fn m_for_tau(tau_max: usize) -> usize {
        (tau_max + 3) / 2
    }

    /// Estimated registered signatures (pre-build memory check; the eval
    /// harness uses this to reproduce the paper's SIFT out-of-memory).
    pub fn estimate_postings(set: &SketchSet, tau_max: usize) -> u128 {
        let m = Self::m_for_tau(tau_max).min(set.l());
        let ranges = block_ranges(set.l(), m);
        let mut total: u128 = 0;
        for (lo, hi) in ranges {
            let lj = hi - lo;
            let per = if set.b() <= 2 {
                1 + lj * ((1usize << set.b()) - 1)
            } else {
                1 + lj
            };
            total += (set.n() as u128) * per as u128;
        }
        total
    }

    pub fn build(set: &SketchSet, tau_max: usize) -> Self {
        let b = set.b();
        let m = Self::m_for_tau(tau_max).min(set.l());
        let ranges = block_ranges(set.l(), m);
        let scheme = if b <= 2 { Scheme::Substitution } else { Scheme::Deletion };

        let blocks = ranges
            .iter()
            .map(|&(lo, hi)| {
                let block_set = set.slice_block(lo, hi);
                let lj = hi - lo;
                let n = set.n();
                let sigma = 1usize << b;
                let per = match scheme {
                    Scheme::Substitution => 1 + lj * (sigma - 1),
                    Scheme::Deletion => 1 + lj,
                };
                let index = HashIndex::build(n * per, || {
                    // generator re-run per pass: enumerate (key, id) pairs
                    let block_set = &block_set;
                    (0..n).flat_map(move |i| {
                        let row = block_set.row(i);
                        let mut keys = Vec::with_capacity(per);
                        match scheme {
                            Scheme::Substitution => {
                                keys.push(sub_key(&row, b));
                                let mut r = row.clone();
                                for pos in 0..lj {
                                    let orig = r[pos];
                                    for c in 0..sigma as u8 {
                                        if c != orig {
                                            r[pos] = c;
                                            keys.push(sub_key(&r, b));
                                        }
                                    }
                                    r[pos] = orig;
                                }
                            }
                            Scheme::Deletion => {
                                keys.push(sub_key(&row, b));
                                for pos in 0..lj {
                                    keys.push(del_key(&row, pos, b));
                                }
                            }
                        }
                        keys.into_iter().map(move |k| (k, i as u32))
                    })
                });
                Block { index, lo, hi, scheme }
            })
            .collect();

        HmSearch {
            blocks,
            b,
            tau_max,
            vertical: VerticalSet::from_horizontal(set),
            state: Mutex::new(ProbeState {
                epochs: vec![0u32; set.n()],
                cur: 0,
                cands: Vec::new(),
            }),
        }
    }

    pub fn m(&self) -> usize {
        self.blocks.len()
    }
}

/// Persistence: per-block signature indexes + the verification store.
/// The visited-epoch array is query-time-only and rebuilt on load.
impl Persist for HmSearch {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.tau_max);
        w.put_usize(self.blocks.len());
        for blk in &self.blocks {
            w.put_usize(blk.lo);
            w.put_usize(blk.hi);
            w.put_u8(match blk.scheme {
                Scheme::Substitution => 0,
                Scheme::Deletion => 1,
            });
            blk.index.write_into(w);
        }
        self.vertical.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let tau_max = r.get_usize()?;
        let m = r.get_usize()?;
        // tau_max feeds m_for_tau's `tau_max + 3` — bound it first.
        ensure(matches!(b, 1 | 2 | 4 | 8) && tau_max <= 4096 && m >= 1 && m <= 4096, || {
            format!("HmSearch: bad shape b={b} tau_max={tau_max} m={m}")
        })?;
        let expect_scheme = if b <= 2 { Scheme::Substitution } else { Scheme::Deletion };
        let mut blocks = Vec::with_capacity(m);
        for _ in 0..m {
            let lo = r.get_usize()?;
            let hi = r.get_usize()?;
            let scheme = match r.get_u8()? {
                0 => Scheme::Substitution,
                1 => Scheme::Deletion,
                t => return Err(StoreError::Corrupt(format!("HmSearch: unknown scheme {t}"))),
            };
            ensure(scheme == expect_scheme, || {
                "HmSearch: signature scheme disagrees with alphabet width".to_string()
            })?;
            let index = HashIndex::read_from(r)?;
            blocks.push(Block { index, lo, hi, scheme });
        }
        let vertical = VerticalSet::read_from(r)?;
        let l = vertical.l();
        ensure(vertical.b() == b, || "HmSearch: verification store b mismatch".to_string())?;
        ensure(m == Self::m_for_tau(tau_max).min(l), || {
            format!("HmSearch: {m} blocks disagree with tau_max={tau_max}, L={l}")
        })?;
        let mut expect = 0usize;
        for blk in &blocks {
            ensure(blk.lo == expect && blk.hi > blk.lo, || {
                format!("HmSearch: block range {}..{} does not tile", blk.lo, blk.hi)
            })?;
            expect = blk.hi;
        }
        ensure(expect == l, || format!("HmSearch: blocks cover {expect} of L={l}"))?;
        let n = vertical.n();
        for (j, blk) in blocks.iter().enumerate() {
            // Emitted ids index the epoch array and the verification
            // store — bound them at load, not at query time.
            ensure(blk.index.max_posting().map_or(true, |m| (m as usize) < n), || {
                format!("HmSearch: block {j} emits ids beyond n={n}")
            })?;
        }
        Ok(HmSearch {
            blocks,
            b,
            tau_max,
            vertical,
            state: Mutex::new(ProbeState { epochs: vec![0u32; n], cur: 0, cands: Vec::new() }),
        })
    }
}

impl HmSearch {
    /// Lock-free probe core: the caller holds the probe-state guard.
    /// Blocked execution acquires the lock once per query block; each
    /// member query probes and verifies its deduplicated candidate
    /// buffers in exactly the serial order.
    fn run_locked(&self, state: &mut ProbeState, q: &[u8], c: &mut dyn Collector) {
        let tau = c.tau();
        assert!(
            tau <= self.tau_max,
            "HmSearch built for tau <= {}, got {tau}",
            self.tau_max
        );
        let q_planes = self.vertical.pack_query(q);
        let ProbeState { epochs, cur, cands } = state;
        *cur = cur.wrapping_add(1);
        if *cur == 0 {
            epochs.fill(0);
            *cur = 1;
        }
        for blk in &self.blocks {
            let q_block = &q[blk.lo..blk.hi];
            let mut probe = |key: u64, c: &mut dyn Collector| {
                // Dedup the probe's posting list, then verify the
                // surviving candidates in one batched kernel call.
                cands.clear();
                for &id in blk.index.get(key) {
                    let e = &mut epochs[id as usize];
                    if *e != *cur {
                        *e = *cur;
                        cands.push(id);
                    }
                }
                // The posting list is sorted and the epoch filter keeps
                // order, but sort anyway so the kernel's monotone-id
                // streaming never depends on a filter implementation
                // detail (near-sorted input makes this pass cheap).
                cands.sort_unstable();
                self.vertical.ham_many_leq(cands, &q_planes, c.tau(), |id, verdict| {
                    if let Some(d) = verdict {
                        c.emit(&[id], d);
                    }
                    Some(c.tau())
                });
            };
            match blk.scheme {
                Scheme::Substitution => {
                    // db registered all 1-substitutions → exact probe only
                    probe(sub_key(q_block, self.b), &mut *c);
                }
                Scheme::Deletion => {
                    // probe exact + every query-side deletion
                    probe(sub_key(q_block, self.b), &mut *c);
                    for pos in 0..q_block.len() {
                        probe(del_key(q_block, pos, self.b), &mut *c);
                    }
                }
            }
        }
    }
}

impl SearchIndex for HmSearch {
    fn run(&self, q: &[u8], _ctx: &mut QueryCtx, c: &mut dyn Collector) {
        let mut guard = self.state.lock().unwrap();
        self.run_locked(&mut guard, q, c);
    }

    fn run_block(
        &self,
        qs: &[&[u8]],
        _ctx: &mut QueryCtx,
        bc: &mut crate::query::BlockCollector,
    ) {
        assert_eq!(qs.len(), bc.len(), "query block / collector slot mismatch");
        // One lock acquisition for the whole block; every member τ must
        // fit the bucket this instance was built for.
        let mut guard = self.state.lock().unwrap();
        for (j, q) in qs.iter().enumerate() {
            let mut slot = crate::query::SlotRef::new(bc, j);
            self.run_locked(&mut guard, q, &mut slot);
        }
    }

    fn heap_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.index.heap_bytes())
            .sum::<usize>()
            + self.vertical.heap_bytes()
            + self.state.lock().unwrap().epochs.heap_bytes()
    }

    fn name(&self) -> String {
        format!("HmSearch (tau<={}, m={})", self.tau_max, self.m())
    }

    fn max_tau(&self) -> Option<usize> {
        Some(self.tau_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn clustered(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..10)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut row = centers[rng.below_usize(10)].clone();
                for _ in 0..rng.below_usize(5) {
                    let p = rng.below_usize(l);
                    row[p] = rng.below(1 << b) as u8;
                }
                row
            })
            .collect()
    }

    fn check(b: usize, l: usize, seed: u64) {
        let rows = clustered(b, l, 500, seed);
        let set = SketchSet::from_rows(b, l, &rows);
        let mut rng = Rng::new(seed + 1);
        for tau_max in [1usize, 2, 3, 4, 5] {
            let hm = HmSearch::build(&set, tau_max);
            for _ in 0..6 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in 0..=tau_max {
                    let mut got = hm.search(&q, tau);
                    got.sort();
                    let expect: Vec<u32> = (0..rows.len())
                        .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                        .map(|i| i as u32)
                        .collect();
                    assert_eq!(got, expect, "b={b} tau_max={tau_max} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn substitution_scheme_matches_scan() {
        check(2, 16, 81); // b=2 → substitution
        check(1, 16, 82);
    }

    #[test]
    fn deletion_scheme_matches_scan() {
        check(4, 12, 83); // b=4 → deletion
        check(8, 8, 84);
    }

    #[test]
    fn m_matches_table4_buckets() {
        assert_eq!(HmSearch::m_for_tau(1), 2);
        assert_eq!(HmSearch::m_for_tau(2), 2);
        assert_eq!(HmSearch::m_for_tau(3), 3);
        assert_eq!(HmSearch::m_for_tau(4), 3);
        assert_eq!(HmSearch::m_for_tau(5), 4);
    }

    #[test]
    fn memory_blowup_vs_plain_hash() {
        // HmSearch must register far more postings than n·m.
        let rows = clustered(2, 16, 1000, 85);
        let set = SketchSet::from_rows(2, 16, &rows);
        let est = HmSearch::estimate_postings(&set, 2);
        assert!(est > 1000 * 2 * 10, "est={est}");
        let hm = HmSearch::build(&set, 2);
        let mih = crate::index::Mih::build(&set, 2);
        assert!(
            hm.heap_bytes() > 4 * crate::index::SearchIndex::heap_bytes(&mih),
            "hm={} mih={}",
            hm.heap_bytes(),
            crate::index::SearchIndex::heap_bytes(&mih)
        );
    }

    #[test]
    fn rejects_tau_above_bucket() {
        let rows = clustered(2, 8, 100, 86);
        let set = SketchSet::from_rows(2, 8, &rows);
        let hm = HmSearch::build(&set, 2);
        assert_eq!(hm.max_tau(), Some(2));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            hm.search(&rows[0], 3)
        }));
        assert!(result.is_err());
    }
}
