//! Signature enumeration: the Hamming ball `{q' : ham(q, q') <= τ}`.
//!
//! The single-index approach (§III-A) generates every such `q'` and probes
//! the inverted index; `|ball| = Σ_{k<=τ} C(L,k)(2^b-1)^k` (Eq. 3), which
//! is the exponential blow-up bST exists to avoid. Blocks in MIH enumerate
//! the same ball over short substrings with small per-block thresholds.
//!
//! Enumeration works on *packed keys*: sketches of `L·b <= 64` bits packed
//! MSB-first (the natural key width for block lengths used in practice;
//! whole-sketch keys up to 64 bits cover every dataset in the paper).

/// Packs a character row into a `u64` key, MSB-first (lexicographic).
#[inline]
pub fn pack_key(row: &[u8], b: usize) -> u64 {
    debug_assert!(row.len() * b <= 64, "key too wide: {}x{}", row.len(), b);
    let mut key = 0u64;
    for &c in row {
        key = (key << b) | c as u64;
    }
    key
}

/// Unpacks a key back into characters (testing/diagnostics).
pub fn unpack_key(mut key: u64, b: usize, l: usize) -> Vec<u8> {
    let mask = (1u64 << b) - 1;
    let mut row = vec![0u8; l];
    for i in (0..l).rev() {
        row[i] = (key & mask) as u8;
        key >>= b;
    }
    row
}

/// Number of signatures `sigs(b, L, τ)` (Eq. 3 of the paper), saturating.
pub fn count_signatures(b: usize, l: usize, tau: usize) -> u128 {
    let sigma_m1 = (1u128 << b) - 1;
    let mut total: u128 = 0;
    for k in 0..=tau.min(l) {
        let mut term = binomial(l, k);
        for _ in 0..k {
            term = term.saturating_mul(sigma_m1);
        }
        total = total.saturating_add(term);
    }
    total
}

/// C(n, k) as u128, saturating.
pub fn binomial(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc.saturating_mul((n - i) as u128) / (i as u128 + 1);
    }
    acc
}

/// Enumerates every signature within Hamming distance `tau` of `row`,
/// invoking `f(key, edits)` for each (including `row` itself at
/// `edits = 0`). `edits` is the signature's exact Hamming distance from
/// `row` — collectors that need distances (top-k over exact-key SIH) read
/// it directly, since an exact-key match implies `ham(s, q) = edits`.
/// Enumeration is depth-first over mismatch positions; keys are packed
/// MSB-first.
///
/// Returns `false` if `f` ever returns `false` (caller-requested abort —
/// used to enforce the paper's 10 s per-query cap on SIH).
pub fn for_each_signature<F: FnMut(u64, usize) -> bool>(
    row: &[u8],
    b: usize,
    tau: usize,
    f: &mut F,
) -> bool {
    let base = pack_key(row, b);
    let l = row.len();
    if !f(base, 0) {
        return false;
    }
    if tau == 0 {
        return true;
    }
    rec(base, row, b, l, 0, tau, 1, f)
}

#[allow(clippy::too_many_arguments)]
fn rec<F: FnMut(u64, usize) -> bool>(
    key: u64,
    row: &[u8],
    b: usize,
    l: usize,
    from: usize,
    budget: usize,
    edits: usize,
    f: &mut F,
) -> bool {
    let sigma = 1u64 << b;
    for pos in from..l {
        let shift = (l - 1 - pos) * b;
        let orig = row[pos] as u64;
        let cleared = key & !(((sigma - 1) << shift) as u64);
        for c in 0..sigma {
            if c == orig {
                continue;
            }
            let k2 = cleared | (c << shift);
            if !f(k2, edits) {
                return false;
            }
            if budget > 1 && !rec(k2, row, b, l, pos + 1, budget - 1, edits + 1, f) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use std::collections::HashSet;

    #[test]
    fn pack_unpack_roundtrip() {
        let row = vec![3u8, 0, 2, 1];
        let key = pack_key(&row, 2);
        assert_eq!(key, 0b11_00_10_01);
        assert_eq!(unpack_key(key, 2, 4), row);
    }

    #[test]
    fn pack_is_lexicographic() {
        let a = pack_key(&[0, 1, 2], 4);
        let b = pack_key(&[0, 2, 0], 4);
        let c = pack_key(&[1, 0, 0], 4);
        assert!(a < b && b < c);
    }

    #[test]
    fn count_matches_formula() {
        // b=1: sigs = Σ C(L,k)
        assert_eq!(count_signatures(1, 4, 1), 1 + 4);
        assert_eq!(count_signatures(1, 4, 2), 1 + 4 + 6);
        // b=2: C(4,1)*3 = 12
        assert_eq!(count_signatures(2, 4, 1), 1 + 12);
        assert_eq!(count_signatures(2, 4, 2), 1 + 12 + 6 * 9);
        // paper's example magnitudes: b=4, L=32, tau=3
        let s = count_signatures(4, 32, 3);
        assert_eq!(s, 1 + 32 * 15 + binomial(32, 2) * 225 + binomial(32, 3) * 3375);
    }

    #[test]
    fn enumeration_is_exact_ball() {
        for &(b, l, tau) in
            &[(1usize, 6usize, 2usize), (2, 4, 2), (2, 5, 3), (4, 3, 2), (8, 2, 1)]
        {
            let row: Vec<u8> = (0..l).map(|i| (i % (1 << b)) as u8).collect();
            let mut got = HashSet::new();
            for_each_signature(&row, b, tau, &mut |k, edits| {
                assert!(got.insert(k), "duplicate signature {k:#x}");
                assert_eq!(
                    edits,
                    ham_chars(&unpack_key(k, b, l), &row),
                    "edit count must equal the signature's distance"
                );
                true
            });
            assert_eq!(got.len() as u128, count_signatures(b, l, tau), "b={b} l={l} tau={tau}");
            // every signature is within tau; and every ball member present
            for &k in &got {
                let r = unpack_key(k, b, l);
                assert!(ham_chars(&r, &row) <= tau);
            }
        }
    }

    #[test]
    fn enumeration_covers_whole_ball_bruteforce() {
        let b = 2usize;
        let l = 4usize;
        let row = vec![1u8, 3, 0, 2];
        for tau in 0..=4 {
            let mut got = HashSet::new();
            for_each_signature(&row, b, tau, &mut |k, _edits| {
                got.insert(k);
                true
            });
            // brute force all 4^4 strings
            for x in 0u64..256 {
                let r = unpack_key(x, b, l);
                let inside = ham_chars(&r, &row) <= tau;
                assert_eq!(got.contains(&x), inside, "tau={tau} x={x:#x}");
            }
        }
    }

    #[test]
    fn abort_stops_enumeration() {
        let row = vec![0u8; 8];
        let mut count = 0usize;
        let completed = for_each_signature(&row, 2, 3, &mut |_, _| {
            count += 1;
            count < 10
        });
        assert!(!completed);
        assert_eq!(count, 10);
    }

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(5, 5), 1);
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(64, 32) > 1u128 << 60, true);
        assert_eq!(binomial(3, 5), 0);
    }
}
