//! Binary serialization of sketch databases.
//!
//! Format (little-endian):
//! ```text
//! magic   u64  = 0x62_53_54_53_4b_45_54_31  ("bSTSKET1")
//! b, l, n u64 × 3
//! words   u64 × n·⌈l·b/64⌉
//! ```
//! Used by `bst sketch --out` / `bst build --in` so expensive sketching
//! runs once per dataset and the eval harness reloads from disk.

use crate::sketch::SketchSet;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Result, Write};
use std::path::Path;

const MAGIC: u64 = 0x6253_5453_4b45_5431;

/// Writes a sketch set to `path`.
pub fn save_sketches(set: &SketchSet, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for v in [MAGIC, set.b() as u64, set.l() as u64, set.n() as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    for &word in set.raw_words() {
        w.write_all(&word.to_le_bytes())?;
    }
    w.flush()
}

/// Reads a sketch set from `path`.
pub fn load_sketches(path: &Path) -> Result<SketchSet> {
    let mut r = BufReader::new(File::open(path)?);
    let mut buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut buf)?;
        Ok(u64::from_le_bytes(buf))
    };
    let magic = read_u64(&mut r)?;
    if magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad magic {magic:#x}: not a bst sketch file"),
        ));
    }
    let b = read_u64(&mut r)? as usize;
    let l = read_u64(&mut r)? as usize;
    let n = read_u64(&mut r)? as usize;
    let wps = (l * b).div_ceil(64);
    let mut bytes = vec![0u8; n * wps * 8];
    r.read_exact(&mut bytes)?;
    let words: Vec<u64> = bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(SketchSet::from_raw(b, l, n, words))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<u8>> = (0..100)
            .map(|_| (0..32).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 32, &rows);
        let dir = std::env::temp_dir().join("bst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sketches.bin");
        save_sketches(&set, &path).unwrap();
        let loaded = load_sketches(&path).unwrap();
        assert_eq!(loaded.b(), 2);
        assert_eq!(loaded.l(), 32);
        assert_eq!(loaded.n(), 100);
        assert_eq!(loaded.raw_words(), set.raw_words());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_sketches(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
