//! Binary serialization of sketch databases.
//!
//! Format (little-endian):
//! ```text
//! magic   u64  = 0x62_53_54_53_4b_45_54_31  ("bSTSKET1")
//! b, l, n u64 × 3
//! words   u64 × n·⌈l·b/64⌉
//! ```
//! Used by `bst sketch --out` / `bst build --in` so expensive sketching
//! runs once per dataset and the eval harness reloads from disk.

use crate::sketch::SketchSet;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Result, Write};
use std::path::Path;

const MAGIC: u64 = 0x6253_5453_4b45_5431;

/// Writes a sketch set to `path`.
pub fn save_sketches(set: &SketchSet, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for v in [MAGIC, set.b() as u64, set.l() as u64, set.n() as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    for &word in set.raw_words() {
        w.write_all(&word.to_le_bytes())?;
    }
    w.flush()
}

fn bad_data(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Reads a sketch set from `path`.
///
/// The header is fully validated before any data-sized allocation: the
/// dimensions must be representable (`b ∈ {1,2,4,8}`, supported `L`,
/// checked size arithmetic) and the file length must equal the declared
/// payload exactly — truncated *and* oversized files are rejected, so a
/// corrupt header can neither over-allocate nor silently misparse.
pub fn load_sketches(path: &Path) -> Result<SketchSet> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut buf)?;
        Ok(u64::from_le_bytes(buf))
    };
    let magic = read_u64(&mut r)?;
    if magic != MAGIC {
        return Err(bad_data(format!("bad magic {magic:#x}: not a bst sketch file")));
    }
    let b64 = read_u64(&mut r)?;
    let l64 = read_u64(&mut r)?;
    let n64 = read_u64(&mut r)?;
    if !matches!(b64, 1 | 2 | 4 | 8) {
        return Err(bad_data(format!("invalid bits-per-char b={b64}")));
    }
    let b = b64 as usize;
    let l = usize::try_from(l64).map_err(|_| bad_data(format!("L={l64} out of range")))?;
    if l < 1 || !l.checked_mul(b).is_some_and(|x| x <= 64 * 64) {
        return Err(bad_data(format!("unsupported sketch length L={l} (b={b})")));
    }
    let n = usize::try_from(n64).map_err(|_| bad_data(format!("n={n64} out of range")))?;
    let wps = (l * b).div_ceil(64);
    let payload = n
        .checked_mul(wps)
        .and_then(|w| w.checked_mul(8))
        .ok_or_else(|| bad_data(format!("n={n} overflows the payload size")))?;
    let declared = 32u64
        .checked_add(payload as u64)
        .ok_or_else(|| bad_data("declared size overflows".into()))?;
    if file_len != declared {
        return Err(bad_data(format!(
            "file is {file_len} bytes but the header declares {declared} \
             (n={n}, wps={wps}): truncated or trailing garbage"
        )));
    }
    let mut bytes = vec![0u8; payload];
    r.read_exact(&mut bytes)?;
    let words: Vec<u64> = bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(SketchSet::from_raw(b, l, n, words))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<u8>> = (0..100)
            .map(|_| (0..32).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 32, &rows);
        let dir = std::env::temp_dir().join("bst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sketches.bin");
        save_sketches(&set, &path).unwrap();
        let loaded = load_sketches(&path).unwrap();
        assert_eq!(loaded.b(), 2);
        assert_eq!(loaded.l(), 32);
        assert_eq!(loaded.n(), 100);
        assert_eq!(loaded.raw_words(), set.raw_words());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_sketches(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    fn saved_sample(name: &str) -> (std::path::PathBuf, Vec<u8>) {
        let rows: Vec<Vec<u8>> = (0..10).map(|i| vec![(i % 4) as u8; 8]).collect();
        let set = SketchSet::from_rows(2, 8, &rows);
        let dir = std::env::temp_dir().join("bst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        save_sketches(&set, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        (path, bytes)
    }

    #[test]
    fn rejects_truncated_file() {
        let (path, bytes) = saved_sample("trunc.bin");
        for cut in [0usize, 7, 31, 33, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(load_sketches(&path).is_err(), "cut={cut}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_oversized_file() {
        let (path, mut bytes) = saved_sample("oversize.bin");
        bytes.extend_from_slice(&[0u8; 16]); // trailing garbage
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_sketches(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_invalid_header_fields() {
        let (path, good) = saved_sample("header.bin");
        // b = 3 (not in {1,2,4,8})
        let mut bad = good.clone();
        bad[8] = 3;
        std::fs::write(&path, &bad).unwrap();
        assert!(load_sketches(&path).is_err());
        // l = 0
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        assert!(load_sketches(&path).is_err());
        // n so large that n*wps*8 overflows usize — must error cleanly,
        // not allocate
        let mut bad = good.clone();
        bad[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        assert!(load_sketches(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
