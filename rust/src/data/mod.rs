//! Synthetic dataset generators standing in for the paper's corpora.
//!
//! The paper evaluates on four real datasets (Table I):
//!
//! | name   | n             | hashing       | L  | b |
//! |--------|---------------|---------------|----|---|
//! | Review | 12,886,488    | b-bit minhash | 16 | 2 |
//! | CP     | 216,121,626   | b-bit minhash | 32 | 2 |
//! | SIFT   | 1,000,000,000 | 0-bit CWS     | 32 | 4 |
//! | GIST   | 79,302,017    | 0-bit CWS     | 64 | 8 |
//!
//! Those corpora (Amazon reviews, compound–protein pairs, BIGANN,
//! 80M tiny images) are not available here, so we synthesize workloads
//! with the *same structure the index sees*: clustered feature vectors
//! whose sketches exhibit realistic near-neighbor populations (Table II
//! reports hundreds-to-thousands of solutions per query — pure uniform
//! sketches would have none). Each item is a perturbed copy of a cluster
//! center plus a background of unclustered items; perturbation strength is
//! drawn per item so query difficulty varies. See DESIGN.md §5.
//!
//! Default sizes are scaled down (×`scale` to grow):
//! Review 200k, CP 400k, SIFT 1M, GIST 500k.

pub mod io;

use crate::sketch::{CwsParams, MinhashParams, SketchSet};
use crate::util::rng::{Rng, Zipf};

/// The four benchmark dataset families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    Review,
    Cp,
    Sift,
    Gist,
}

impl Dataset {
    pub const ALL: [Dataset; 4] = [Dataset::Review, Dataset::Cp, Dataset::Sift, Dataset::Gist];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Review => "review",
            Dataset::Cp => "cp",
            Dataset::Sift => "sift",
            Dataset::Gist => "gist",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "review" => Some(Dataset::Review),
            "cp" => Some(Dataset::Cp),
            "sift" => Some(Dataset::Sift),
            "gist" => Some(Dataset::Gist),
            _ => None,
        }
    }

    /// Sketch parameters from Table I.
    pub fn b(&self) -> usize {
        match self {
            Dataset::Review | Dataset::Cp => 2,
            Dataset::Sift => 4,
            Dataset::Gist => 8,
        }
    }

    pub fn l(&self) -> usize {
        match self {
            Dataset::Review => 16,
            Dataset::Cp | Dataset::Sift => 32,
            Dataset::Gist => 64,
        }
    }

    /// Whether sketching uses minhash (set data) or CWS (dense data).
    pub fn uses_minhash(&self) -> bool {
        matches!(self, Dataset::Review | Dataset::Cp)
    }

    /// Feature dimensionality of the synthetic generator. The paper's
    /// fingerprints are millions-dimensional; only the hashing kernel sees
    /// `D`, the index never does, so we use a compact vocabulary.
    pub fn dim(&self) -> usize {
        match self {
            Dataset::Review | Dataset::Cp => 4096,
            Dataset::Sift => 128,
            Dataset::Gist => 384,
        }
    }

    /// Default database size at `scale = 1.0`.
    pub fn default_n(&self) -> usize {
        match self {
            Dataset::Review => 200_000,
            Dataset::Cp => 400_000,
            Dataset::Sift => 1_000_000,
            Dataset::Gist => 500_000,
        }
    }

    /// The paper's full-size n (for extrapolation tables).
    pub fn paper_n(&self) -> usize {
        match self {
            Dataset::Review => 12_886_488,
            Dataset::Cp => 216_121_626,
            Dataset::Sift => 1_000_000_000,
            Dataset::Gist => 79_302_017,
        }
    }
}

/// Generation knobs shared by the set and dense generators.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Number of items.
    pub n: usize,
    /// Master seed.
    pub seed: u64,
    /// Number of worker threads for sketching.
    pub threads: usize,
    /// Average cluster size (items per center).
    pub cluster_size: usize,
    /// Fraction of unclustered background items.
    pub background: f64,
}

impl GenConfig {
    pub fn for_dataset(ds: Dataset, scale: f64, seed: u64, threads: usize) -> Self {
        GenConfig {
            n: ((ds.default_n() as f64 * scale) as usize).max(1000),
            seed,
            threads: threads.max(1),
            cluster_size: 24,
            background: 0.10,
        }
    }
}

/// Generates set fingerprints (present-index lists) for Review/CP-like data:
/// Zipf-distributed vocabularies, per-item element swaps against a cluster
/// center set.
pub fn generate_sets(ds: Dataset, cfg: &GenConfig) -> Vec<Vec<u32>> {
    assert!(ds.uses_minhash());
    let d = ds.dim();
    let mut rng = Rng::new(cfg.seed ^ 0x5e75);
    let zipf = Zipf::new(d, 1.05);
    let n_clustered = ((1.0 - cfg.background) * cfg.n as f64) as usize;
    let n_centers = (n_clustered / cfg.cluster_size).max(1);

    let sample_set = |rng: &mut Rng, size: usize| -> Vec<u32> {
        let mut set = std::collections::BTreeSet::new();
        while set.len() < size {
            set.insert(zipf.sample(rng) as u32);
        }
        set.into_iter().collect()
    };

    // Cluster centers: word sets of 80–160 elements.
    let centers: Vec<Vec<u32>> = (0..n_centers)
        .map(|_| {
            let size = 80 + rng.below_usize(80);
            sample_set(&mut rng, size)
        })
        .collect();

    let mut out = Vec::with_capacity(cfg.n);
    for i in 0..cfg.n {
        if i < n_clustered {
            let center = &centers[i % n_centers];
            // Swap out a random fraction of the center's elements. The
            // fourth-power skew makes near-duplicates common (real corpora
            // are dominated by them — that is what Table II's hundreds of
            // small-τ solutions reflect) while keeping a long tail of
            // heavily-edited variants.
            let u = rng.f64();
            let swap_frac = 0.5 * u * u * u * u;
            let mut set: std::collections::BTreeSet<u32> = center
                .iter()
                .filter(|_| rng.f64() >= swap_frac)
                .copied()
                .collect();
            let additions = (center.len() as f64 * swap_frac) as usize;
            while set.len() < center.len().min(set.len() + additions) {
                set.insert(zipf.sample(&mut rng) as u32);
            }
            if set.is_empty() {
                set.insert(zipf.sample(&mut rng) as u32);
            }
            out.push(set.into_iter().collect());
        } else {
            let size = 60 + rng.below_usize(120);
            out.push(sample_set(&mut rng, size));
        }
    }
    out
}

/// Generates dense non-negative feature vectors (row-major `n × dim`) for
/// SIFT/GIST-like data: mixture of half-normal cluster centers with
/// per-item noise of varying strength.
pub fn generate_dense(ds: Dataset, cfg: &GenConfig) -> Vec<f32> {
    assert!(!ds.uses_minhash());
    let d = ds.dim();
    let mut rng = Rng::new(cfg.seed ^ 0xde5e);
    let n_clustered = ((1.0 - cfg.background) * cfg.n as f64) as usize;
    let n_centers = (n_clustered / cfg.cluster_size).max(1);
    let centers: Vec<f32> = (0..n_centers * d)
        .map(|_| rng.normal().abs() as f32)
        .collect();

    let mut out = vec![0f32; cfg.n * d];
    for i in 0..cfg.n {
        let row = &mut out[i * d..(i + 1) * d];
        if i < n_clustered {
            let c = (i % n_centers) * d;
            // Fourth-power skew: most items sit very close to their
            // center (near-duplicate descriptors), few are far.
            let u = rng.f64() as f32;
            let sigma = 0.005 + 0.4 * u * u * u * u;
            for (j, r) in row.iter_mut().enumerate() {
                *r = (centers[c + j] + sigma * rng.normal() as f32).max(0.0);
            }
        } else {
            for r in row.iter_mut() {
                *r = rng.normal().abs() as f32;
            }
        }
    }
    out
}

/// A fully-sketched dataset plus its query set and hashing parameters.
pub struct Workload {
    pub dataset: Dataset,
    pub sketches: SketchSet,
    /// Query rows (sampled database members, as in the paper).
    pub queries: Vec<Vec<u8>>,
    /// Hashing parameters (kept so the runtime example can re-sketch via XLA).
    pub minhash: Option<MinhashParams>,
    pub cws: Option<CwsParams>,
}

/// Number of queries sampled per dataset (paper: 1,000).
pub const N_QUERIES: usize = 1000;

/// Generates the complete workload for a dataset: features → sketches →
/// sampled queries. Deterministic in `cfg.seed`.
pub fn generate_workload(ds: Dataset, cfg: &GenConfig) -> Workload {
    let (sketches, minhash, cws) = if ds.uses_minhash() {
        let params = MinhashParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
        let sets = generate_sets(ds, cfg);
        let sketches = params.sketch_batch(&sets, cfg.threads);
        (sketches, Some(params), None)
    } else {
        let params = CwsParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
        let feats = generate_dense(ds, cfg);
        let sketches = params.sketch_batch(&feats, cfg.n, cfg.threads);
        (sketches, None, Some(params))
    };
    let mut rng = Rng::new(cfg.seed ^ 0x9e51e5);
    let n_q = N_QUERIES.min(cfg.n);
    let queries = rng
        .sample_indices(cfg.n, n_q)
        .into_iter()
        .map(|i| sketches.row(i))
        .collect();
    Workload { dataset: ds, sketches, queries, minhash, cws }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(n: usize) -> GenConfig {
        GenConfig { n, seed: 42, threads: 2, cluster_size: 8, background: 0.1 }
    }

    #[test]
    fn dataset_table1_parameters() {
        assert_eq!(Dataset::Review.b(), 2);
        assert_eq!(Dataset::Review.l(), 16);
        assert_eq!(Dataset::Cp.b(), 2);
        assert_eq!(Dataset::Cp.l(), 32);
        assert_eq!(Dataset::Sift.b(), 4);
        assert_eq!(Dataset::Sift.l(), 32);
        assert_eq!(Dataset::Gist.b(), 8);
        assert_eq!(Dataset::Gist.l(), 64);
        assert_eq!(Dataset::parse("SIFT"), Some(Dataset::Sift));
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn sets_are_valid_and_nonempty() {
        let sets = generate_sets(Dataset::Review, &tiny_cfg(500));
        assert_eq!(sets.len(), 500);
        for s in &sets {
            assert!(!s.is_empty());
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(s.iter().all(|&j| (j as usize) < Dataset::Review.dim()));
        }
    }

    #[test]
    fn dense_is_nonnegative() {
        let xs = generate_dense(Dataset::Sift, &tiny_cfg(200));
        assert_eq!(xs.len(), 200 * 128);
        assert!(xs.iter().all(|&x| x >= 0.0));
        assert!(xs.iter().any(|&x| x > 0.0));
    }

    #[test]
    fn workload_shape_and_determinism() {
        let cfg = tiny_cfg(1200);
        let w1 = generate_workload(Dataset::Review, &cfg);
        let w2 = generate_workload(Dataset::Review, &cfg);
        assert_eq!(w1.sketches.n(), 1200);
        assert_eq!(w1.sketches.l(), 16);
        assert_eq!(w1.queries.len(), N_QUERIES);
        assert_eq!(w1.sketches.raw_words(), w2.sketches.raw_words());
        assert_eq!(w1.queries, w2.queries);
    }

    #[test]
    fn clustering_produces_near_neighbors() {
        // The core requirement: queries must have non-trivial neighbor sets
        // at small tau (Table II), unlike uniform random sketches.
        let cfg = tiny_cfg(2000);
        let w = generate_workload(Dataset::Cp, &cfg);
        let vert = crate::sketch::VerticalSet::from_horizontal(&w.sketches);
        let mut total = 0usize;
        for q in w.queries.iter().take(50) {
            total += vert.scan(q, 3).len();
        }
        // every query matches itself; clustered data must add more.
        assert!(total > 50 * 2, "avg solutions too small: {}", total as f64 / 50.0);
    }

    #[test]
    fn cws_workload_generates() {
        let cfg = GenConfig { n: 800, seed: 7, threads: 2, cluster_size: 8, background: 0.1 };
        let w = generate_workload(Dataset::Sift, &cfg);
        assert_eq!(w.sketches.b(), 4);
        assert!(w.cws.is_some() && w.minhash.is_none());
    }
}
