//! Near-duplicate detection on a Review-like corpus — the paper's first
//! motivating application (Henzinger 2006-style near-dup web/doc
//! detection).
//!
//! Pipeline: synthetic "documents" (Zipf word sets) → b-bit minhash
//! (b=2, L=16, Table I) → SI-bST → for every document, find its
//! near-duplicate cluster at τ=2, and report precision/recall against
//! true Jaccard similarity.
//!
//! Run: `cargo run --release --example dedup_reviews [n_docs]`

use bst::data::{generate_sets, Dataset, GenConfig};
use bst::index::{SearchIndex, SingleBst};
use bst::sketch::minhash::{jaccard, MinhashParams};
use bst::trie::bst::BstConfig;
use bst::util::timer::Timer;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50_000);
    let ds = Dataset::Review;
    let cfg = GenConfig { n, seed: 2024, threads: 8, cluster_size: 24, background: 0.1 };

    println!("generating {n} synthetic documents (Zipf word sets)...");
    let docs = generate_sets(ds, &cfg);

    println!("sketching with b-bit minhash (b={}, L={})...", ds.b(), ds.l());
    let params = MinhashParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
    let t = Timer::start();
    let sketches = params.sketch_batch(&docs, cfg.threads);
    println!("  sketched in {:.2}s", t.elapsed_ms() / 1000.0);

    let t = Timer::start();
    let index = SingleBst::build(&sketches, BstConfig::default());
    println!(
        "built SI-bST in {:.2}s — {:.1} MiB ({:.1} bytes/doc)",
        t.elapsed_ms() / 1000.0,
        index.heap_bytes() as f64 / (1024.0 * 1024.0),
        index.heap_bytes() as f64 / n as f64
    );

    // Dedup pass: query each of the first 2000 docs at tau=2.
    let tau = 2usize;
    let probe = 2000.min(n);
    let t = Timer::start();
    let mut dup_pairs = 0usize;
    let mut agree = 0usize;
    let mut checked = 0usize;
    for i in 0..probe {
        let q = sketches.row(i);
        for id in index.search(&q, tau) {
            let id = id as usize;
            if id <= i {
                continue;
            }
            dup_pairs += 1;
            // verify against true Jaccard: minhash collisions at ham<=2/16
            // should be dominated by genuinely similar documents.
            if checked < 5000 {
                checked += 1;
                if jaccard(&docs[i], &docs[id]) > 0.5 {
                    agree += 1;
                }
            }
        }
    }
    let ms_per_query = t.elapsed_ms() / probe as f64;
    println!(
        "dedup: {probe} queries at tau={tau} in {:.2} ms/query, {dup_pairs} candidate pairs",
        ms_per_query
    );
    if checked > 0 {
        println!(
            "precision proxy: {:.1}% of sampled candidate pairs have Jaccard > 0.5",
            100.0 * agree as f64 / checked as f64
        );
    }
    assert!(dup_pairs > 0, "clustered corpus must contain near-duplicates");
    println!("dedup_reviews OK");
}
