//! END-TO-END DRIVER — the full three-layer system on a real workload:
//!
//!  1. **Layer 2/1 (build path)**: generate CP-like set fingerprints and
//!     sketch them *through the PJRT runtime* (the AOT JAX/Pallas
//!     `sketch_cp` artifact — Python is not running; the HLO was lowered
//!     by `make artifacts`). Verified bit-identical to the native path.
//!  2. **Build once, serve from snapshot**: build the sharded SI-bST
//!     engine, save it as a versioned snapshot (`Engine::save`), drop it,
//!     and cold-start the serving engine with `Engine::load` — the
//!     production restart path: no re-sort, no trie reconstruction, no
//!     rank/select re-indexing.
//!  3. **Layer 3 (request path)**: start the TCP server with dynamic
//!     batching over the *loaded* engine and drive it with concurrent
//!     closed-loop clients; report served-throughput + latency
//!     percentiles and the server's own metrics (EXPERIMENTS.md §E2E).
//!
//! Run: `make artifacts && cargo run --release --example serve_pipeline [n]`

use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::coordinator::{server, ServeConfig};
use bst::data::{generate_sets, Dataset, GenConfig};
use bst::runtime::Runtime;
use bst::sketch::MinhashParams;
use bst::trie::bst::BstConfig;
use bst::util::json::Json;
use bst::util::timer::{Stats, Timer};
use bst::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let ds = Dataset::Cp;
    let cfg = GenConfig { n, seed: 11, threads: 8, cluster_size: 24, background: 0.1 };

    // ---- Layer 2/1: ingestion through the AOT artifact ----------------
    println!("[1/4] generating {n} CP-like fingerprints...");
    let sets = generate_sets(ds, &cfg);
    let params = MinhashParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);

    println!("[2/4] sketching via PJRT (artifact sketch_cp, interpret-mode Pallas)...");
    let rt = Runtime::load(Path::new("artifacts")).expect("run `make artifacts` first");
    let sk = rt.sketcher(ds.name()).expect("sketcher");
    let d = ds.dim();
    let mut x = vec![0f32; n * d];
    for (i, s) in sets.iter().enumerate() {
        for &j in s {
            x[i * d + j as usize] = 1.0;
        }
    }
    let t = Timer::start();
    let sketches = sk.sketch_minhash(&x, n, &params).expect("xla sketch");
    let ingest_s = t.elapsed_ms() / 1000.0;
    println!(
        "      {} sketches in {:.1}s ({:.0} items/s) via XLA",
        n,
        ingest_s,
        n as f64 / ingest_s
    );
    // cross-check a sample against the native implementation
    for i in (0..n).step_by(n / 50 + 1) {
        assert_eq!(sketches.row(i), params.sketch_set(&sets[i]), "xla/native divergence");
    }

    // ---- Build once, snapshot, cold-start ------------------------------
    println!("[3/4] build once → snapshot → serve-from-snapshot cold start...");
    let serve_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        shards: std::thread::available_parallelism().map_or(4, |p| p.get()),
        ..Default::default()
    };
    let t = Timer::start();
    let built = Engine::build(
        &sketches,
        serve_cfg.shards,
        &ShardIndexKind::Bst(BstConfig::default()),
    );
    let build_s = t.elapsed_ms() / 1000.0;
    let snap_path = std::env::temp_dir().join("serve_pipeline_engine.snap");
    built.save(&snap_path).expect("save snapshot");
    let disk_mib = std::fs::metadata(&snap_path).map_or(0.0, |m| m.len() as f64 / (1 << 20) as f64);
    drop(built); // the serving engine comes purely from cold storage
    let t = Timer::start();
    let engine = Arc::new(Engine::load(&snap_path).expect("load snapshot"));
    let load_s = t.elapsed_ms() / 1000.0;
    println!(
        "      engine: {} shards, {:.1} MiB heap / {disk_mib:.1} MiB disk; \
         built in {build_s:.1}s, cold-started in {load_s:.2}s",
        engine.n_shards(),
        engine.heap_bytes() as f64 / (1 << 20) as f64,
    );
    let handle = server::serve(Arc::clone(&engine), serve_cfg).expect("serve");
    let addr = handle.addr;

    // ---- Load generation ------------------------------------------------
    let clients = 8usize;
    let per_client = 250usize;
    let tau = 3usize;
    println!("[4/4] driving {clients} closed-loop clients × {per_client} queries (tau={tau})...");
    let wall = Timer::start();
    let mut joins = Vec::new();
    for c in 0..clients {
        let sketches = sketches.clone();
        joins.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).expect("connect");
            stream.set_nodelay(true).expect("nodelay");
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut rng = Rng::new(c as u64 ^ 0xC11E);
            let mut lat = Stats::new();
            let mut hits = 0usize;
            for _ in 0..per_client {
                let q = sketches.row(rng.below_usize(sketches.n()));
                let req = format!(
                    "{{\"op\":\"search\",\"q\":[{}],\"tau\":{tau}}}\n",
                    q.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
                );
                let t = Timer::start();
                writer.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                lat.push(t.elapsed_us());
                let resp = Json::parse(line.trim()).expect("json");
                hits += resp.get("ids").and_then(|a| a.as_arr()).map_or(0, |a| a.len());
            }
            (lat, hits)
        }));
    }
    let mut all = Stats::new();
    let mut total_hits = 0usize;
    for j in joins {
        let (mut lat, hits) = j.join().unwrap();
        total_hits += hits;
        for p in [50.0, 99.0] {
            let _ = lat.percentile(p);
        }
        for i in 0..lat.len() {
            let _ = i;
        }
        // merge: Stats has no merge; re-push via percentile samples is
        // lossy — instead aggregate client stats by pushing summary means.
        all.push(lat.mean());
    }
    let wall_s = wall.elapsed_ms() / 1000.0;
    let total_q = clients * per_client;

    let metrics = engine.metrics();
    println!("\n===== E2E REPORT (CP-like, n={n}) =====");
    println!("ingestion (XLA)   : {:.0} items/s", n as f64 / ingest_s);
    println!("served queries    : {total_q} in {wall_s:.2}s = {:.0} q/s", total_q as f64 / wall_s);
    println!("avg hits/query    : {:.1}", total_hits as f64 / total_q as f64);
    println!("client mean lat   : {:.0} us (mean of per-client means)", all.mean());
    println!(
        "server p50/p99    : {} / {} us",
        metrics.latency_percentile_us(50.0),
        metrics.latency_percentile_us(99.0)
    );
    println!("server batches    : {}", metrics.batches.load(std::sync::atomic::Ordering::Relaxed));
    println!("engine index size : {:.1} MiB", engine.heap_bytes() as f64 / (1 << 20) as f64);

    assert_eq!(
        metrics.queries.load(std::sync::atomic::Ordering::Relaxed) as usize,
        total_q
    );
    handle.stop();
    let _ = std::fs::remove_file(&snap_path);
    println!("serve_pipeline OK");
}
