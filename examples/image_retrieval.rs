//! Content-based image retrieval on a SIFT-like workload — the paper's
//! second motivating application (BIGANN-style descriptor search).
//!
//! Pipeline: synthetic 128-dim descriptors (clustered, non-negative) →
//! 0-bit CWS (b=4, L=32, Table I) → compare SI-bST against MI-bST and
//! the bit-parallel linear scan across τ = 1..5, reporting the speedups
//! and recall@τ against the scan ground truth (always 100% — all methods
//! are exact; the assert pins that).
//!
//! Run: `cargo run --release --example image_retrieval [n_descriptors]`

use bst::data::{generate_dense, Dataset, GenConfig};
use bst::index::{LinearScan, MultiBst, SearchIndex, SingleBst};
use bst::sketch::cws::CwsParams;
use bst::trie::bst::BstConfig;
use bst::util::timer::Timer;
use bst::util::Rng;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let ds = Dataset::Sift;
    let cfg = GenConfig { n, seed: 7, threads: 8, cluster_size: 24, background: 0.1 };

    println!("generating {n} synthetic SIFT-like descriptors...");
    let feats = generate_dense(ds, &cfg);

    println!("sketching with 0-bit CWS (b={}, L={})...", ds.b(), ds.l());
    let params = CwsParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
    let t = Timer::start();
    let sketches = params.sketch_batch(&feats, n, cfg.threads);
    println!("  sketched in {:.2}s", t.elapsed_ms() / 1000.0);

    let scan = LinearScan::build(&sketches);
    let si = SingleBst::build(&sketches, BstConfig::default());
    let mi = MultiBst::build(&sketches, 2);
    println!(
        "index sizes: scan {:.1} MiB | SI-bST {:.1} MiB | MI-bST {:.1} MiB",
        scan.heap_bytes() as f64 / (1 << 20) as f64,
        si.heap_bytes() as f64 / (1 << 20) as f64,
        SearchIndex::heap_bytes(&mi) as f64 / (1 << 20) as f64,
    );

    let mut rng = Rng::new(99);
    let queries: Vec<Vec<u8>> = (0..50)
        .map(|_| sketches.row(rng.below_usize(n)))
        .collect();

    println!(
        "\n{:>4} {:>12} {:>12} {:>12} {:>10} {:>8}",
        "tau", "scan ms", "SI-bST ms", "MI-bST ms", "speedup", "avg hits"
    );
    for tau in 1..=5usize {
        let time = |f: &dyn Fn(&[u8]) -> Vec<u32>| -> (f64, usize) {
            let t = Timer::start();
            let mut hits = 0;
            for q in &queries {
                hits += f(q).len();
            }
            (t.elapsed_ms() / queries.len() as f64, hits / queries.len())
        };
        let (scan_ms, scan_hits) = time(&|q| scan.search(q, tau));
        let (si_ms, si_hits) = time(&|q| si.search(q, tau));
        let (mi_ms, mi_hits) = time(&|q| mi.search(q, tau));
        assert_eq!(scan_hits, si_hits, "SI-bST must be exact");
        assert_eq!(scan_hits, mi_hits, "MI-bST must be exact");
        println!(
            "{tau:>4} {scan_ms:>12.3} {si_ms:>12.3} {mi_ms:>12.3} {:>9.1}x {scan_hits:>8}",
            scan_ms / si_ms.min(mi_ms)
        );
    }
    println!("\nrecall@tau = 100% for both tries (exact methods; asserted)");
    println!("image_retrieval OK");
}
