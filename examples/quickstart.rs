//! Quickstart: build a bST index over a handful of 2-bit sketches and run
//! Hamming-threshold queries — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use bst::index::{SearchIndex, SingleBst};
use bst::sketch::SketchSet;
use bst::trie::bst::BstConfig;
use bst::trie::SketchTrie;

fn main() {
    // The paper's Figure 1 database: eleven 2-bit sketches of length 5
    // over alphabet {a,b,c,d} = {0,1,2,3}.
    let names = [
        "baabb", "aaaaa", "baaaa", "caaca", "caaca", "aaaaa", "caaca", "ddccc",
        "abaab", "bcbcb", "ddddd",
    ];
    let rows: Vec<Vec<u8>> = names
        .iter()
        .map(|s| s.bytes().map(|c| c - b'a').collect())
        .collect();
    let set = SketchSet::from_rows(/*b=*/ 2, /*L=*/ 5, &rows);

    // Build SI-bST (single-index b-bit sketch trie).
    let index = SingleBst::build(&set, BstConfig::default());
    println!("index: {}", index.trie().describe());
    println!("size : {} bytes", index.heap_bytes());

    // Query "aaaaa" at increasing thresholds (Figure 1 uses tau = 1).
    let q: Vec<u8> = "aaaaa".bytes().map(|c| c - b'a').collect();
    for tau in 0..=2 {
        let mut hits = index.search(&q, tau);
        hits.sort();
        let names: Vec<&str> = hits.iter().map(|&i| names[i as usize]).collect();
        println!("tau={tau}: ids={hits:?} sketches={names:?}");
    }

    // tau=1 must find the two exact copies of "aaaaa" and "baaaa".
    let mut hits = index.search(&q, 1);
    hits.sort();
    assert_eq!(hits, vec![1, 2, 5]);
    println!("quickstart OK");
}
